//! Typed experiment configuration + the paper's default parameterization.
//!
//! Defaults encode Table I (server/device specs) and Table II (simulation
//! parameters) exactly; everything is overridable from a TOML file
//! (`--config`) and/or CLI flags (see `cli`).

use crate::util::json::Json;

use super::toml::{self, TomlError};

/// Channel states used in Fig. 4 — pathloss exponents 2/4/6 (§V-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelState {
    Good,
    Normal,
    Poor,
}

impl ChannelState {
    pub fn pathloss_exp(self) -> f64 {
        match self {
            ChannelState::Good => 2.0,
            ChannelState::Normal => 4.0,
            ChannelState::Poor => 6.0,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "good" => Some(ChannelState::Good),
            "normal" => Some(ChannelState::Normal),
            "poor" => Some(ChannelState::Poor),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ChannelState::Good => "Good",
            ChannelState::Normal => "Normal",
            ChannelState::Poor => "Poor",
        }
    }

    pub const ALL: [ChannelState; 3] =
        [ChannelState::Good, ChannelState::Normal, ChannelState::Poor];
}

/// Edge-server compute spec (Table I row 1 + Table II δ^S, ξ).
#[derive(Clone, Debug)]
pub struct ServerSpec {
    pub platform: String,
    /// F^S_max — maximum GPU core frequency [Hz]
    pub max_freq_hz: f64,
    /// σ^S — GPU core count
    pub cores: f64,
    /// δ^S — FLOPs per core per cycle
    pub flops_per_cycle: f64,
    /// ξ — power coefficient [W/(Hz)³]: P = ξ·f³ (Eq. 11)
    pub xi: f64,
}

impl Default for ServerSpec {
    fn default() -> Self {
        Self {
            platform: "Nvidia RTX 4060Ti".into(),
            max_freq_hz: 2.46e9,
            cores: 3072.0,
            flops_per_cycle: 2.0,
            xi: 1e-25,
        }
    }
}

impl ServerSpec {
    /// Peak throughput f·δ·σ [FLOP/s] at frequency `f`.
    pub fn throughput(&self, f_hz: f64) -> f64 {
        f_hz * self.flops_per_cycle * self.cores
    }
}

/// Edge-device compute spec (Table I rows 2-6 + Table II δ^D_m).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: String,
    pub platform: String,
    /// f^D_m — GPU core frequency [Hz] (devices run at a fixed clock)
    pub freq_hz: f64,
    /// σ^D_m — GPU core count
    pub cores: f64,
    /// δ^D_m — FLOPs per core per cycle
    pub flops_per_cycle: f64,
    /// distance to the AP [m] (simulated placement; see DESIGN.md §2)
    pub distance_m: f64,
}

impl DeviceSpec {
    /// Peak throughput f·δ·σ [FLOP/s].
    pub fn throughput(&self) -> f64 {
        self.freq_hz * self.flops_per_cycle * self.cores
    }

    /// F^{m,S}_min = f^D_m δ^D_m σ^D_m / (δ^S σ^S) — the paper's server
    /// frequency floor (server must out-compute the device).
    pub fn server_freq_floor(&self, server: &ServerSpec) -> f64 {
        self.throughput() / (server.flops_per_cycle * server.cores)
    }
}

/// Table I defaults.  Distances are the simulated placements (5–45 m
/// from the AP) used for every figure; they are config-overridable.
pub fn default_devices() -> Vec<DeviceSpec> {
    let mk = |name: &str, platform: &str, ghz: f64, cores: f64, dist: f64| DeviceSpec {
        name: name.into(),
        platform: platform.into(),
        freq_hz: ghz * 1e9,
        cores,
        flops_per_cycle: 2.0,
        distance_m: dist,
    };
    vec![
        mk("Device 1", "Jetson AGX Orin", 1.3, 2048.0, 10.0),
        mk("Device 2", "Jetson AGX Orin", 1.0, 2048.0, 15.0),
        mk("Device 3", "Jetson AGX Orin", 0.7, 1792.0, 20.0),
        mk("Device 4", "Jetson Orin NX", 0.7, 1024.0, 25.0),
        mk("Device 5", "Jetson AGX Nano", 0.5, 512.0, 30.0),
    ]
}

/// Which fading *process* generates the per-round channel gains
/// (DESIGN.md §13).  All three are counter-indexed: the gain of any
/// `(device, round)` cell is a pure O(1) function of the seed, so the
/// parallel engines stay bit-identical to serial under every model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FadingModel {
    /// Memoryless Rayleigh block fading — one i.i.d. |CN(0,1)|² draw
    /// per link per round (the paper's model, and the default).
    Iid,
    /// Gauss–Markov (AR(1)) correlated Rayleigh fading with lag-1
    /// field autocorrelation `rho`, realized as a windowed moving
    /// average of counter-indexed Gaussian innovations.
    Markov,
    /// Jakes-spectrum fading: sum of `paths` sinusoids with
    /// device-seeded phases/arrival angles and normalized Doppler
    /// `doppler` per round.
    Jakes,
}

impl FadingModel {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "iid" => Some(FadingModel::Iid),
            "markov" | "ar1" | "gauss-markov" => Some(FadingModel::Markov),
            "jakes" => Some(FadingModel::Jakes),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FadingModel::Iid => "iid",
            FadingModel::Markov => "markov",
            FadingModel::Jakes => "jakes",
        }
    }

    pub const ALL: [FadingModel; 3] = [FadingModel::Iid, FadingModel::Markov, FadingModel::Jakes];
}

/// `[channel.process]` — the pluggable fading process and its knobs.
/// Parameters irrelevant to the selected model are ignored.
#[derive(Clone, Debug)]
pub struct FadingProcessSpec {
    pub model: FadingModel,
    /// markov: lag-1 field autocorrelation ρ ∈ [0, 1)
    pub rho: f64,
    /// markov: moving-average window W (innovations remembered; the
    /// lag-τ autocorrelation is ρ^τ up to a ρ^{2(W-τ)} truncation term)
    pub window: usize,
    /// jakes: normalized Doppler per round, f_D·T_round
    pub doppler: f64,
    /// jakes: number of sum-of-sinusoid propagation paths
    pub paths: usize,
}

impl Default for FadingProcessSpec {
    fn default() -> Self {
        Self {
            model: FadingModel::Iid,
            rho: 0.9,
            window: 32,
            doppler: 0.05,
            paths: 16,
        }
    }
}

/// Device mobility model for the `[mobility]` table (DESIGN.md §13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MobilityModel {
    /// Frozen placement — `DeviceSpec::distance_m` for every round (the
    /// paper's setting, and the default).
    Static,
    /// Constant-velocity straight line along a device-seeded heading.
    Linear,
    /// Ping-pong between the start position and a device-seeded
    /// waypoint at most `range_m` away.
    Waypoint,
}

impl MobilityModel {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(MobilityModel::Static),
            "linear" => Some(MobilityModel::Linear),
            "waypoint" | "waypoint-loop" => Some(MobilityModel::Waypoint),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MobilityModel::Static => "static",
            MobilityModel::Linear => "linear",
            MobilityModel::Waypoint => "waypoint",
        }
    }
}

/// `[mobility]` — turns the per-device placement into a per-round
/// distance trajectory with a closed-form position at any round.
#[derive(Clone, Debug)]
pub struct MobilitySpec {
    pub model: MobilityModel,
    /// device speed [m/s]
    pub speed_mps: f64,
    /// virtual seconds of movement per training round (the mobility
    /// clock tick — rounds, not wall time, index the trajectory)
    pub round_s: f64,
    /// waypoint: maximum excursion from the start placement [m]
    pub range_m: f64,
    /// distance floor so trajectories never cross the AP [m]
    pub min_distance_m: f64,
}

impl Default for MobilitySpec {
    fn default() -> Self {
        Self {
            model: MobilityModel::Static,
            speed_mps: 1.0,
            round_s: 1.0,
            range_m: 25.0,
            min_distance_m: 1.0,
        }
    }
}

impl MobilitySpec {
    /// Static placements keep the placement-pure mean-SNR fast path.
    pub fn enabled(&self) -> bool {
        self.model != MobilityModel::Static
    }
}

/// Wireless channel parameterization (3GPP-flavoured; DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct ChannelSpec {
    /// per-link bandwidth B [Hz]
    pub bandwidth_hz: f64,
    /// device TX power [dBm] (uplink)
    pub tx_power_device_dbm: f64,
    /// AP TX power [dBm] (downlink)
    pub tx_power_ap_dbm: f64,
    /// thermal noise density [dBm/Hz]
    pub noise_dbm_per_hz: f64,
    /// receiver noise figure [dB]
    pub noise_figure_db: f64,
    /// reference pathloss at d0 [dB]
    pub pl0_db: f64,
    /// reference distance [m]
    pub d0_m: f64,
    /// Rayleigh block fading per round on/off
    pub fading: bool,
    /// `[channel.process]` — which fading process draws the gains
    pub process: FadingProcessSpec,
}

impl Default for ChannelSpec {
    fn default() -> Self {
        Self {
            bandwidth_hz: 100e6,
            tx_power_device_dbm: 23.0,
            tx_power_ap_dbm: 30.0,
            noise_dbm_per_hz: -174.0,
            noise_figure_db: 9.0,
            pl0_db: 40.0,
            d0_m: 1.0,
            fading: true,
            process: FadingProcessSpec::default(),
        }
    }
}

/// Fine-tuning workload (Table II + §V setup).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// model architecture for the cost model ("llama1b" for figures)
    pub arch: String,
    /// mini-batch size (sequences)
    pub batch_size: usize,
    /// sequence length (tokens)
    pub seq_len: usize,
    /// T_{m,n} — local epochs per round
    pub local_epochs: usize,
    /// N — training rounds
    pub rounds: usize,
    /// φ — compression ratio for smashed data & gradient
    pub phi: f64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self {
            arch: "llama1b".into(),
            batch_size: 8,
            seq_len: 512,
            local_epochs: 5,
            rounds: 20,
            phi: 0.1,
        }
    }
}

/// CARD algorithm knobs (Table II).
#[derive(Clone, Debug)]
pub struct CardSpec {
    /// w — delay/energy weighting in Eq. (12)
    pub w: f64,
}

impl Default for CardSpec {
    fn default() -> Self {
        Self { w: 0.2 }
    }
}

/// Poisson device churn for the DES engine (DESIGN.md §11): devices
/// alternate exponential present/away periods.  Rates of 0 (the
/// default) disable churn entirely — every synchronous-engine path and
/// every preset without a `[churn]` table is churn-free.
#[derive(Clone, Debug, Default)]
pub struct ChurnSpec {
    /// departure rate while present [1/s] (mean uptime = 1/rate)
    pub depart_rate_hz: f64,
    /// return rate while away [1/s] (mean away time = 1/rate)
    pub arrive_rate_hz: f64,
}

impl ChurnSpec {
    pub fn enabled(&self) -> bool {
        self.depart_rate_hz > 0.0
    }
}

/// `[faults]` — the DES fault-injection model (DESIGN.md §17): per-link
/// transient outages with bounded retry/backoff, server capacity-slot
/// failures with exponential repair, and correlated regional dropout
/// bursts keyed off device positions.  All injection rates default to 0
/// — a config without a `[faults]` table (or with every rate at 0) is
/// fault-free and bit-identical to the pre-fault engines.
#[derive(Clone, Debug)]
pub struct FaultsSpec {
    /// transient link-outage rate while a transfer is in flight [1/s]
    pub link_outage_rate_hz: f64,
    /// retransmissions allowed per transfer before the cell is dropped
    pub max_retries: usize,
    /// exponential-backoff base wait before a retransmission [s]
    pub backoff_base_s: f64,
    /// multiplicative backoff jitter amplitude in [0, 1]
    pub backoff_jitter: f64,
    /// probability a server capacity slot fails per batch dispatch
    pub slot_fail_prob: f64,
    /// mean exponential repair time of a failed slot [s]
    pub slot_repair_s: f64,
    /// probability per round of a correlated regional dropout burst
    pub burst_rate_per_round: f64,
    /// radius of the burst region around its center device [m]
    pub burst_radius_m: f64,
    /// sync-policy round timeout as a multiple of the semi-sync
    /// deadline estimate (0 disables the timeout; ignored unless an
    /// injection rate is non-zero)
    pub timeout_factor: f64,
}

impl Default for FaultsSpec {
    fn default() -> Self {
        Self {
            link_outage_rate_hz: 0.0,
            max_retries: 3,
            backoff_base_s: 0.5,
            backoff_jitter: 0.5,
            slot_fail_prob: 0.0,
            slot_repair_s: 5.0,
            burst_rate_per_round: 0.0,
            burst_radius_m: 25.0,
            timeout_factor: 0.0,
        }
    }
}

impl FaultsSpec {
    /// Whether any injection channel is live.  When false the DES
    /// engine takes no fault branch and draws no fault stream — the
    /// zero-perturbation anchor `exp::verify` enforces.
    pub fn enabled(&self) -> bool {
        self.link_outage_rate_hz > 0.0
            || self.slot_fail_prob > 0.0
            || self.burst_rate_per_round > 0.0
    }
}

/// Geometric arrangement of the edge-server cell sites for the
/// `[cells]` table (DESIGN.md §15).  Cell 0 always sits at the origin —
/// the legacy single-AP position — so `count = 1` reproduces today's
/// topology exactly under every layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellLayout {
    /// Cells on the positive x-axis at `spacing_m` intervals.
    Line,
    /// Cell 0 at the origin, the rest on a circle of radius `spacing_m`.
    Ring,
    /// Row-major square grid with `spacing_m` pitch.
    Grid,
}

impl CellLayout {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "line" => Some(CellLayout::Line),
            "ring" => Some(CellLayout::Ring),
            "grid" => Some(CellLayout::Grid),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CellLayout::Line => "line",
            CellLayout::Ring => "ring",
            CellLayout::Grid => "grid",
        }
    }

    pub const ALL: [CellLayout; 3] = [CellLayout::Line, CellLayout::Ring, CellLayout::Grid];
}

/// `[cells]` — the multi-cell edge tier (DESIGN.md §15): how many
/// edge servers exist, where they sit, and how sticky the device→cell
/// association is.  `count = 1` (the default) is the single-server
/// topology of the paper and is bit-identical to the pre-cell engines.
#[derive(Clone, Debug)]
pub struct CellsSpec {
    /// number of edge-server cell sites
    pub count: usize,
    /// geometric arrangement of the sites
    pub layout: CellLayout,
    /// inter-site distance [m] (layout pitch / ring radius)
    pub spacing_m: f64,
    /// handover hysteresis margin [dB]: a device switches serving
    /// cells only when the candidate's pathloss is at least this much
    /// lower than the serving cell's
    pub hysteresis_db: f64,
}

impl Default for CellsSpec {
    fn default() -> Self {
        Self {
            count: 1,
            layout: CellLayout::Line,
            spacing_m: 60.0,
            hysteresis_db: 3.0,
        }
    }
}

impl CellsSpec {
    /// Whether the multi-cell tier is active (more than one site).
    pub fn enabled(&self) -> bool {
        self.count > 1
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, Default)]
pub struct ExpConfig {
    pub server: ServerSpec,
    pub devices: Vec<DeviceSpec>,
    pub channel: ChannelSpec,
    pub workload: WorkloadSpec,
    pub card: CardSpec,
    pub churn: ChurnSpec,
    pub faults: FaultsSpec,
    pub mobility: MobilitySpec,
    pub cells: CellsSpec,
    pub seed: u64,
}

impl ExpConfig {
    /// Paper defaults (Tables I + II).
    pub fn paper() -> Self {
        Self {
            server: ServerSpec::default(),
            devices: default_devices(),
            channel: ChannelSpec::default(),
            workload: WorkloadSpec::default(),
            card: CardSpec::default(),
            churn: ChurnSpec::default(),
            faults: FaultsSpec::default(),
            mobility: MobilitySpec::default(),
            cells: CellsSpec::default(),
            seed: 7,
        }
    }

    /// Load from a TOML file, starting from paper defaults — every key
    /// optional.  Unknown keys are rejected to catch typos.
    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        let tree = toml::parse(text)?;
        let mut cfg = ExpConfig::paper();
        apply_tree(&mut cfg, &tree)?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(path.to_string(), e.to_string()))?;
        Self::from_toml_str(&text)
    }

    /// Sanity bounds — called after any override layer.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let inval = |msg: String| Err(ConfigError::Invalid(msg));
        if !(0.0..=1.0).contains(&self.card.w) {
            return inval(format!("card.w must be in [0,1], got {}", self.card.w));
        }
        if !(0.0..=1.0).contains(&self.workload.phi) {
            return inval(format!("workload.phi must be in (0,1], got {}", self.workload.phi));
        }
        if self.devices.is_empty() {
            return inval("at least one device required".into());
        }
        if self.workload.local_epochs == 0 || self.workload.rounds == 0 {
            return inval("local_epochs and rounds must be >= 1".into());
        }
        for (name, rate) in [
            ("churn.depart_rate_hz", self.churn.depart_rate_hz),
            ("churn.arrive_rate_hz", self.churn.arrive_rate_hz),
        ] {
            if !rate.is_finite() || rate < 0.0 {
                return inval(format!("{name} must be finite and >= 0, got {rate}"));
            }
        }
        let fl = &self.faults;
        if !fl.link_outage_rate_hz.is_finite() || fl.link_outage_rate_hz < 0.0 {
            return inval(format!(
                "faults.link_outage_rate_hz must be finite and >= 0, got {}",
                fl.link_outage_rate_hz
            ));
        }
        if fl.max_retries > 16 {
            return inval(format!(
                "faults.max_retries must be in [0, 16], got {}",
                fl.max_retries
            ));
        }
        for (name, v) in [
            ("faults.backoff_base_s", fl.backoff_base_s),
            ("faults.slot_repair_s", fl.slot_repair_s),
            ("faults.burst_radius_m", fl.burst_radius_m),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return inval(format!("{name} must be finite and > 0, got {v}"));
            }
        }
        if !fl.backoff_jitter.is_finite() || !(0.0..=1.0).contains(&fl.backoff_jitter) {
            return inval(format!(
                "faults.backoff_jitter must be in [0, 1], got {}",
                fl.backoff_jitter
            ));
        }
        if !fl.slot_fail_prob.is_finite() || !(0.0..1.0).contains(&fl.slot_fail_prob) {
            return inval(format!(
                "faults.slot_fail_prob must be in [0, 1), got {}",
                fl.slot_fail_prob
            ));
        }
        if !fl.burst_rate_per_round.is_finite() || !(0.0..=1.0).contains(&fl.burst_rate_per_round) {
            return inval(format!(
                "faults.burst_rate_per_round must be in [0, 1], got {}",
                fl.burst_rate_per_round
            ));
        }
        if !fl.timeout_factor.is_finite() || fl.timeout_factor < 0.0 {
            return inval(format!(
                "faults.timeout_factor must be finite and >= 0, got {}",
                fl.timeout_factor
            ));
        }
        let p = &self.channel.process;
        if !p.rho.is_finite() || !(0.0..1.0).contains(&p.rho) {
            return inval(format!("channel.process.rho must be in [0,1), got {}", p.rho));
        }
        if p.window == 0 || p.window > 4096 {
            return inval(format!(
                "channel.process.window must be in [1, 4096], got {}",
                p.window
            ));
        }
        if !p.doppler.is_finite() || p.doppler < 0.0 {
            return inval(format!(
                "channel.process.doppler must be finite and >= 0, got {}",
                p.doppler
            ));
        }
        if p.paths == 0 || p.paths > 1024 {
            return inval(format!(
                "channel.process.paths must be in [1, 1024], got {}",
                p.paths
            ));
        }
        let m = &self.mobility;
        for (name, v) in [
            ("mobility.speed_mps", m.speed_mps),
            ("mobility.range_m", m.range_m),
        ] {
            if !v.is_finite() || v < 0.0 {
                return inval(format!("{name} must be finite and >= 0, got {v}"));
            }
        }
        for (name, v) in [
            ("mobility.round_s", m.round_s),
            ("mobility.min_distance_m", m.min_distance_m),
        ] {
            if !v.is_finite() || v <= 0.0 {
                return inval(format!("{name} must be finite and > 0, got {v}"));
            }
        }
        let cells = &self.cells;
        if cells.count == 0 || cells.count > 4096 {
            return inval(format!("cells.count must be in [1, 4096], got {}", cells.count));
        }
        if !cells.spacing_m.is_finite() || cells.spacing_m <= 0.0 {
            return inval(format!(
                "cells.spacing_m must be finite and > 0, got {}",
                cells.spacing_m
            ));
        }
        if !cells.hysteresis_db.is_finite() || cells.hysteresis_db < 0.0 {
            return inval(format!(
                "cells.hysteresis_db must be finite and >= 0, got {}",
                cells.hysteresis_db
            ));
        }
        for d in &self.devices {
            if d.server_freq_floor(&self.server) > self.server.max_freq_hz {
                return inval(format!(
                    "{}: F_min ({:.3e}) exceeds server F_max ({:.3e}) — the paper \
                     assumes the server out-computes every device",
                    d.name,
                    d.server_freq_floor(&self.server),
                    self.server.max_freq_hz
                ));
            }
        }
        Ok(())
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Io(String, String),
    Toml(TomlError),
    Invalid(String),
    UnknownKey(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(path, e) => write!(f, "failed to read {path}: {e}"),
            ConfigError::Toml(e) => write!(f, "{e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
            ConfigError::UnknownKey(key) => write!(f, "unknown config key: {key}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<TomlError> for ConfigError {
    fn from(e: TomlError) -> Self {
        ConfigError::Toml(e)
    }
}

// ---------------------------------------------------------------------------
// tree -> struct application (explicit, so typos are caught)
// ---------------------------------------------------------------------------

fn apply_tree(cfg: &mut ExpConfig, tree: &Json) -> Result<(), ConfigError> {
    let obj = tree
        .as_obj()
        .ok_or_else(|| ConfigError::Invalid("root must be a table".into()))?;
    for (key, val) in obj {
        match key.as_str() {
            "server" => apply_server(&mut cfg.server, val)?,
            "devices" => {
                let arr = val
                    .as_arr()
                    .ok_or_else(|| ConfigError::Invalid("devices must be [[devices]]".into()))?;
                cfg.devices = arr
                    .iter()
                    .map(parse_device)
                    .collect::<Result<Vec<_>, _>>()?;
            }
            "channel" => apply_channel(&mut cfg.channel, val)?,
            "workload" => apply_workload(&mut cfg.workload, val)?,
            "card" => apply_card(&mut cfg.card, val)?,
            "churn" => apply_churn(&mut cfg.churn, val)?,
            "faults" => apply_faults(&mut cfg.faults, val)?,
            "mobility" => apply_mobility(&mut cfg.mobility, val)?,
            "cells" => apply_cells(&mut cfg.cells, val)?,
            "sim" => {
                for (k, v) in val.as_obj().into_iter().flatten() {
                    match k.as_str() {
                        "seed" => cfg.seed = num(v, "sim.seed")? as u64,
                        _ => return Err(ConfigError::UnknownKey(format!("sim.{k}"))),
                    }
                }
            }
            _ => return Err(ConfigError::UnknownKey(key.clone())),
        }
    }
    Ok(())
}

fn num(v: &Json, what: &str) -> Result<f64, ConfigError> {
    v.as_f64()
        .ok_or_else(|| ConfigError::Invalid(format!("{what} must be a number")))
}

fn string(v: &Json, what: &str) -> Result<String, ConfigError> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| ConfigError::Invalid(format!("{what} must be a string")))
}

fn apply_server(s: &mut ServerSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "platform" => s.platform = string(v, "server.platform")?,
            "max_freq_ghz" => s.max_freq_hz = num(v, "server.max_freq_ghz")? * 1e9,
            "cores" => s.cores = num(v, "server.cores")?,
            "flops_per_cycle" => s.flops_per_cycle = num(v, "server.flops_per_cycle")?,
            "xi" => s.xi = num(v, "server.xi")?,
            _ => return Err(ConfigError::UnknownKey(format!("server.{k}"))),
        }
    }
    Ok(())
}

fn parse_device(val: &Json) -> Result<DeviceSpec, ConfigError> {
    let mut d = DeviceSpec {
        name: "device".into(),
        platform: "unknown".into(),
        freq_hz: 1e9,
        cores: 1024.0,
        flops_per_cycle: 2.0,
        distance_m: 20.0,
    };
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "name" => d.name = string(v, "devices.name")?,
            "platform" => d.platform = string(v, "devices.platform")?,
            "freq_ghz" => d.freq_hz = num(v, "devices.freq_ghz")? * 1e9,
            "cores" => d.cores = num(v, "devices.cores")?,
            "flops_per_cycle" => d.flops_per_cycle = num(v, "devices.flops_per_cycle")?,
            "distance_m" => d.distance_m = num(v, "devices.distance_m")?,
            _ => return Err(ConfigError::UnknownKey(format!("devices.{k}"))),
        }
    }
    Ok(d)
}

fn apply_channel(c: &mut ChannelSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "bandwidth_mhz" => c.bandwidth_hz = num(v, "channel.bandwidth_mhz")? * 1e6,
            "tx_power_device_dbm" => c.tx_power_device_dbm = num(v, k)?,
            "tx_power_ap_dbm" => c.tx_power_ap_dbm = num(v, k)?,
            "noise_dbm_per_hz" => c.noise_dbm_per_hz = num(v, k)?,
            "noise_figure_db" => c.noise_figure_db = num(v, k)?,
            "pl0_db" => c.pl0_db = num(v, k)?,
            "d0_m" => c.d0_m = num(v, k)?,
            "fading" => {
                c.fading = matches!(v, Json::Bool(true));
            }
            "process" => apply_fading_process(&mut c.process, v)?,
            _ => return Err(ConfigError::UnknownKey(format!("channel.{k}"))),
        }
    }
    Ok(())
}

fn apply_fading_process(p: &mut FadingProcessSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "model" => {
                let s = string(v, "channel.process.model")?;
                p.model = FadingModel::parse(&s).ok_or_else(|| {
                    ConfigError::Invalid(format!(
                        "channel.process.model must be iid|markov|jakes, got '{s}'"
                    ))
                })?;
            }
            "rho" => p.rho = num(v, "channel.process.rho")?,
            "window" => p.window = num(v, "channel.process.window")? as usize,
            "doppler" => p.doppler = num(v, "channel.process.doppler")?,
            "paths" => p.paths = num(v, "channel.process.paths")? as usize,
            _ => return Err(ConfigError::UnknownKey(format!("channel.process.{k}"))),
        }
    }
    Ok(())
}

fn apply_mobility(m: &mut MobilitySpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "model" => {
                let s = string(v, "mobility.model")?;
                m.model = MobilityModel::parse(&s).ok_or_else(|| {
                    ConfigError::Invalid(format!(
                        "mobility.model must be static|linear|waypoint, got '{s}'"
                    ))
                })?;
            }
            "speed_mps" => m.speed_mps = num(v, "mobility.speed_mps")?,
            "round_s" => m.round_s = num(v, "mobility.round_s")?,
            "range_m" => m.range_m = num(v, "mobility.range_m")?,
            "min_distance_m" => m.min_distance_m = num(v, "mobility.min_distance_m")?,
            _ => return Err(ConfigError::UnknownKey(format!("mobility.{k}"))),
        }
    }
    Ok(())
}

fn apply_workload(w: &mut WorkloadSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "arch" => w.arch = string(v, "workload.arch")?,
            "batch_size" => w.batch_size = num(v, k)? as usize,
            "seq_len" => w.seq_len = num(v, k)? as usize,
            "local_epochs" => w.local_epochs = num(v, k)? as usize,
            "rounds" => w.rounds = num(v, k)? as usize,
            "phi" => w.phi = num(v, k)?,
            _ => return Err(ConfigError::UnknownKey(format!("workload.{k}"))),
        }
    }
    Ok(())
}

fn apply_card(c: &mut CardSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "w" => c.w = num(v, "card.w")?,
            _ => return Err(ConfigError::UnknownKey(format!("card.{k}"))),
        }
    }
    Ok(())
}

fn apply_cells(c: &mut CellsSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "count" => c.count = num(v, "cells.count")? as usize,
            "layout" => {
                let s = string(v, "cells.layout")?;
                c.layout = CellLayout::parse(&s).ok_or_else(|| {
                    ConfigError::Invalid(format!("cells.layout must be line|ring|grid, got '{s}'"))
                })?;
            }
            "spacing_m" => c.spacing_m = num(v, "cells.spacing_m")?,
            "hysteresis_db" => c.hysteresis_db = num(v, "cells.hysteresis_db")?,
            _ => return Err(ConfigError::UnknownKey(format!("cells.{k}"))),
        }
    }
    Ok(())
}

fn apply_churn(c: &mut ChurnSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "depart_rate_hz" => c.depart_rate_hz = num(v, "churn.depart_rate_hz")?,
            "arrive_rate_hz" => c.arrive_rate_hz = num(v, "churn.arrive_rate_hz")?,
            _ => return Err(ConfigError::UnknownKey(format!("churn.{k}"))),
        }
    }
    Ok(())
}

fn apply_faults(f: &mut FaultsSpec, val: &Json) -> Result<(), ConfigError> {
    for (k, v) in val.as_obj().into_iter().flatten() {
        match k.as_str() {
            "link_outage_rate_hz" => f.link_outage_rate_hz = num(v, "faults.link_outage_rate_hz")?,
            "max_retries" => f.max_retries = num(v, "faults.max_retries")? as usize,
            "backoff_base_s" => f.backoff_base_s = num(v, "faults.backoff_base_s")?,
            "backoff_jitter" => f.backoff_jitter = num(v, "faults.backoff_jitter")?,
            "slot_fail_prob" => f.slot_fail_prob = num(v, "faults.slot_fail_prob")?,
            "slot_repair_s" => f.slot_repair_s = num(v, "faults.slot_repair_s")?,
            "burst_rate_per_round" => f.burst_rate_per_round = num(v, "faults.burst_rate_per_round")?,
            "burst_radius_m" => f.burst_radius_m = num(v, "faults.burst_radius_m")?,
            "timeout_factor" => f.timeout_factor = num(v, "faults.timeout_factor")?,
            _ => return Err(ConfigError::UnknownKey(format!("faults.{k}"))),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults_match_tables() {
        let c = ExpConfig::paper();
        // Table I
        assert_eq!(c.server.max_freq_hz, 2.46e9);
        assert_eq!(c.server.cores, 3072.0);
        assert_eq!(c.devices.len(), 5);
        assert_eq!(c.devices[0].freq_hz, 1.3e9);
        assert_eq!(c.devices[4].cores, 512.0);
        // Table II
        assert_eq!(c.server.flops_per_cycle, 2.0);
        assert_eq!(c.server.xi, 1e-25);
        assert_eq!(c.card.w, 0.2);
        assert_eq!(c.workload.local_epochs, 5);
        assert_eq!(c.workload.phi, 0.1);
        c.validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let c = ExpConfig::from_toml_str(
            r#"
            [card]
            w = 0.5
            [workload]
            rounds = 3
            [channel]
            bandwidth_mhz = 20
            [[devices]]
            name = "solo"
            freq_ghz = 0.9
            cores = 256
            "#,
        )
        .unwrap();
        assert_eq!(c.card.w, 0.5);
        assert_eq!(c.workload.rounds, 3);
        assert_eq!(c.channel.bandwidth_hz, 20e6);
        assert_eq!(c.devices.len(), 1);
        assert_eq!(c.devices[0].freq_hz, 0.9e9);
        // untouched defaults survive
        assert_eq!(c.workload.phi, 0.1);
    }

    #[test]
    fn churn_defaults_off_and_overrides_parse() {
        let c = ExpConfig::paper();
        assert!(!c.churn.enabled());
        let c = ExpConfig::from_toml_str(
            "[churn]\ndepart_rate_hz = 0.001\narrive_rate_hz = 0.01\n",
        )
        .unwrap();
        assert!(c.churn.enabled());
        assert_eq!(c.churn.depart_rate_hz, 0.001);
        assert_eq!(c.churn.arrive_rate_hz, 0.01);
        c.validate().unwrap();
        let mut bad = ExpConfig::paper();
        bad.churn.depart_rate_hz = -1.0;
        assert!(bad.validate().is_err());
        assert!(matches!(
            ExpConfig::from_toml_str("[churn]\nrate = 1\n"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn faults_default_off_and_overrides_parse() {
        let c = ExpConfig::paper();
        assert!(!c.faults.enabled());
        assert_eq!(c.faults.max_retries, 3);
        c.validate().unwrap();
        let c = ExpConfig::from_toml_str(
            "[faults]\nlink_outage_rate_hz = 0.2\nmax_retries = 5\nbackoff_base_s = 0.1\n\
             backoff_jitter = 0.3\nslot_fail_prob = 0.05\nslot_repair_s = 2\n\
             burst_rate_per_round = 0.1\nburst_radius_m = 40\ntimeout_factor = 4\n",
        )
        .unwrap();
        assert!(c.faults.enabled());
        assert_eq!(c.faults.link_outage_rate_hz, 0.2);
        assert_eq!(c.faults.max_retries, 5);
        assert_eq!(c.faults.backoff_base_s, 0.1);
        assert_eq!(c.faults.backoff_jitter, 0.3);
        assert_eq!(c.faults.slot_fail_prob, 0.05);
        assert_eq!(c.faults.slot_repair_s, 2.0);
        assert_eq!(c.faults.burst_rate_per_round, 0.1);
        assert_eq!(c.faults.burst_radius_m, 40.0);
        assert_eq!(c.faults.timeout_factor, 4.0);
        c.validate().unwrap();
        assert!(matches!(
            ExpConfig::from_toml_str("[faults]\noutage = 1\n"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn faults_validation_bounds() {
        let mut c = ExpConfig::paper();
        c.faults.link_outage_rate_hz = -0.1;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.max_retries = 17;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.backoff_base_s = 0.0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.backoff_jitter = 1.5;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.slot_fail_prob = 1.0; // a slot that always fails never drains
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.slot_repair_s = f64::NAN;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.burst_rate_per_round = 1.1;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.burst_radius_m = 0.0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.faults.timeout_factor = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn channel_process_defaults_iid_and_overrides_parse() {
        let c = ExpConfig::paper();
        assert_eq!(c.channel.process.model, FadingModel::Iid);
        assert!(!c.mobility.enabled());
        let c = ExpConfig::from_toml_str(
            "[channel.process]\nmodel = \"markov\"\nrho = 0.95\nwindow = 48\n\
             [mobility]\nmodel = \"waypoint\"\nspeed_mps = 12\nround_s = 5\nrange_m = 60\n",
        )
        .unwrap();
        assert_eq!(c.channel.process.model, FadingModel::Markov);
        assert_eq!(c.channel.process.rho, 0.95);
        assert_eq!(c.channel.process.window, 48);
        assert_eq!(c.mobility.model, MobilityModel::Waypoint);
        assert_eq!(c.mobility.speed_mps, 12.0);
        assert_eq!(c.mobility.round_s, 5.0);
        assert_eq!(c.mobility.range_m, 60.0);
        assert!(c.mobility.enabled());
        c.validate().unwrap();
        // untouched process knobs keep their defaults
        assert_eq!(c.channel.process.doppler, 0.05);
        assert_eq!(c.channel.process.paths, 16);
    }

    #[test]
    fn fading_model_and_mobility_parse_names() {
        assert_eq!(FadingModel::parse("IID"), Some(FadingModel::Iid));
        assert_eq!(FadingModel::parse("gauss-markov"), Some(FadingModel::Markov));
        assert_eq!(FadingModel::parse("jakes"), Some(FadingModel::Jakes));
        assert_eq!(FadingModel::parse("rician"), None);
        for m in FadingModel::ALL {
            assert_eq!(FadingModel::parse(m.name()), Some(m));
        }
        assert_eq!(MobilityModel::parse("waypoint-loop"), Some(MobilityModel::Waypoint));
        assert_eq!(MobilityModel::parse("teleport"), None);
    }

    #[test]
    fn process_and_mobility_validation_bounds() {
        let mut c = ExpConfig::paper();
        c.channel.process.rho = 1.0; // divergent AR(1) normalizer
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.channel.process.window = 0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.channel.process.paths = 0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.channel.process.doppler = -0.1;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.mobility.speed_mps = f64::NAN;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.mobility.round_s = 0.0;
        assert!(c.validate().is_err());
        // unknown nested keys are typo errors, not silently ignored
        assert!(matches!(
            ExpConfig::from_toml_str("[channel.process]\nrh = 0.5\n"),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(matches!(
            ExpConfig::from_toml_str("[mobility]\nvelocity = 3\n"),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(ExpConfig::from_toml_str("[channel.process]\nmodel = \"rician\"\n").is_err());
    }

    #[test]
    fn cells_default_single_and_overrides_parse() {
        let c = ExpConfig::paper();
        assert_eq!(c.cells.count, 1);
        assert!(!c.cells.enabled());
        assert_eq!(c.cells.layout, CellLayout::Line);
        let c = ExpConfig::from_toml_str(
            "[cells]\ncount = 4\nlayout = \"grid\"\nspacing_m = 80\nhysteresis_db = 2\n",
        )
        .unwrap();
        assert_eq!(c.cells.count, 4);
        assert!(c.cells.enabled());
        assert_eq!(c.cells.layout, CellLayout::Grid);
        assert_eq!(c.cells.spacing_m, 80.0);
        assert_eq!(c.cells.hysteresis_db, 2.0);
        c.validate().unwrap();
        for l in CellLayout::ALL {
            assert_eq!(CellLayout::parse(l.name()), Some(l));
        }
        assert_eq!(CellLayout::parse("hex"), None);
        assert!(matches!(
            ExpConfig::from_toml_str("[cells]\nsites = 3\n"),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(ExpConfig::from_toml_str("[cells]\nlayout = \"hex\"\n").is_err());
    }

    #[test]
    fn cells_validation_bounds() {
        let mut c = ExpConfig::paper();
        c.cells.count = 0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.cells.count = 5000;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.cells.spacing_m = 0.0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.cells.hysteresis_db = -1.0;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.cells.hysteresis_db = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(matches!(
            ExpConfig::from_toml_str("[card]\nweight = 0.5\n"),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(matches!(
            ExpConfig::from_toml_str("[bogus]\nx = 1\n"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn validation_bounds() {
        let mut c = ExpConfig::paper();
        c.card.w = 1.5;
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.devices.clear();
        assert!(c.validate().is_err());
        c = ExpConfig::paper();
        c.devices[0].freq_hz = 1e12; // faster than the server
        assert!(c.validate().is_err());
    }

    #[test]
    fn server_freq_floor_formula() {
        let c = ExpConfig::paper();
        // Device 1: 1.3e9 * 2 * 2048 / (2 * 3072)
        let f = c.devices[0].server_freq_floor(&c.server);
        assert!((f - 1.3e9 * 2048.0 / 3072.0).abs() < 1.0);
    }
}
