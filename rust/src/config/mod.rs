//! Configuration system: TOML-subset parser + typed schema with the
//! paper's Table I / Table II defaults.

pub mod schema;
pub mod toml;

pub use schema::{
    CardSpec, ChannelSpec, ChannelState, ConfigError, DeviceSpec, ExpConfig, ServerSpec,
    WorkloadSpec,
};
