//! Configuration system: TOML-subset parser + typed schema with the
//! paper's Table I / Table II defaults, plus the scenario registry of
//! TOML-driven fleet-scale presets.

pub mod scenario;
pub mod schema;
pub mod toml;

pub use scenario::Scenario;
pub use schema::{
    CardSpec, CellLayout, CellsSpec, ChannelSpec, ChannelState, ChurnSpec, ConfigError,
    DeviceSpec, ExpConfig, FadingModel, FadingProcessSpec, FaultsSpec, MobilityModel,
    MobilitySpec, ServerSpec, WorkloadSpec,
};
