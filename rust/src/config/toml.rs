//! TOML-subset parser (no `serde`/`toml` offline) producing a
//! `util::json::Json` tree, so typed extraction is shared with the
//! manifest loader.
//!
//! Supported grammar (everything the shipped configs use):
//!   * `[section]`, `[nested.section]`, `[[array.of.tables]]`
//!   * `key = "string" | 123 | 1.5e3 | true | false | [scalars, ...]`
//!   * `#` comments, blank lines
//! Unsupported (rejected loudly): inline tables, multi-line strings,
//! datetimes, dotted keys on the left-hand side.

use std::collections::BTreeMap;

use crate::util::json::Json;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // current insertion path: (path segments, is_array_of_tables)
    let mut path: Vec<String> = Vec::new();
    let mut in_array_table = false;

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError {
            line: lineno + 1,
            msg: msg.to_string(),
        };

        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            path = inner.split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|s| s.is_empty()) {
                return Err(err("empty segment in table name"));
            }
            in_array_table = true;
            // push a fresh element onto the array at `path`
            let arr = resolve_array(&mut root, &path).map_err(|m| err(&m))?;
            arr.push(Json::Obj(BTreeMap::new()));
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            path = inner.split('.').map(|s| s.trim().to_string()).collect();
            if path.iter().any(|s| s.is_empty()) {
                return Err(err("empty segment in table name"));
            }
            in_array_table = false;
            resolve_table(&mut root, &path).map_err(|m| err(&m))?;
        } else if let Some(eq) = find_eq(line) {
            let key = line[..eq].trim();
            let val_src = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val_src).map_err(|m| err(&m))?;
            let table = if in_array_table {
                last_array_elem(&mut root, &path).map_err(|m| err(&m))?
            } else {
                resolve_table(&mut root, &path).map_err(|m| err(&m))?
            };
            if table.insert(key.to_string(), value).is_some() {
                return Err(err(&format!("duplicate key '{key}'")));
            }
        } else {
            return Err(err("expected `[section]` or `key = value`"));
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn resolve_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for seg in path {
        let entry = cur
            .entry(seg.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            Json::Arr(v) => match v.last_mut() {
                Some(Json::Obj(m)) => m,
                _ => return Err(format!("'{seg}' is not a table")),
            },
            _ => return Err(format!("'{seg}' is not a table")),
        };
    }
    Ok(cur)
}

fn resolve_array<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut Vec<Json>, String> {
    let (last, prefix) = path.split_last().ok_or("empty path")?;
    let parent = resolve_table(root, prefix)?;
    let entry = parent
        .entry(last.clone())
        .or_insert_with(|| Json::Arr(Vec::new()));
    match entry {
        Json::Arr(v) => Ok(v),
        _ => Err(format!("'{last}' is not an array of tables")),
    }
}

fn last_array_elem<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let arr = resolve_array(root, path)?;
    match arr.last_mut() {
        Some(Json::Obj(m)) => Ok(m),
        _ => Err("array of tables has no open element".to_string()),
    }
}

fn parse_value(src: &str) -> Result<Json, String> {
    if src.is_empty() {
        return Err("missing value".to_string());
    }
    if let Some(inner) = src.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string (escapes unsupported)".to_string());
        }
        return Ok(Json::Str(inner.to_string()));
    }
    if src == "true" {
        return Ok(Json::Bool(true));
    }
    if src == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = src.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(Json::Arr(out));
    }
    // number (allow underscores like 2_048)
    let cleaned: String = src.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("unparseable value: '{src}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, ch) in s.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sections_and_scalars() {
        let t = parse(
            r#"
            # comment
            top = 1
            [server]
            max_freq_ghz = 2.46   # trailing comment
            cores = 3_072
            name = "RTX 4060Ti"
            [card]
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(t.at(&["top"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(t.at(&["server", "cores"]).unwrap().as_f64(), Some(3072.0));
        assert_eq!(
            t.at(&["server", "name"]).unwrap().as_str(),
            Some("RTX 4060Ti")
        );
        assert_eq!(t.at(&["card", "enabled"]).unwrap(), &Json::Bool(true));
    }

    #[test]
    fn array_of_tables() {
        let t = parse(
            r#"
            [[devices]]
            name = "d1"
            freq = 1.3
            [[devices]]
            name = "d2"
            freq = 1.0
            "#,
        )
        .unwrap();
        let devs = t.at(&["devices"]).unwrap().as_arr().unwrap();
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[1].get("name").unwrap().as_str(), Some("d2"));
    }

    #[test]
    fn nested_sections() {
        let t = parse("[a.b]\nx = 2\n[a.c]\ny = 3\n").unwrap();
        assert_eq!(t.at(&["a", "b", "x"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(t.at(&["a", "c", "y"]).unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn arrays() {
        let t = parse("xs = [1, 2, 3]\nnames = [\"a\", \"b\"]\nempty = []\n").unwrap();
        assert_eq!(t.at(&["xs"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(t.at(&["empty"]).unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse("just words\n").is_err());
        assert!(parse("[unclosed\n").is_err());
        assert!(parse("k = \n").is_err());
        assert!(parse("k = 1\nk = 2\n").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = parse("k = \"a#b\"\n").unwrap();
        assert_eq!(t.at(&["k"]).unwrap().as_str(), Some("a#b"));
    }
}
