//! Online-learning cut policies (DESIGN.md §19): contextual bandits
//! that *learn* the cut decision the CARD oracle computes in closed
//! form.
//!
//! The paper's CARD algorithm assumes the cost model is known and picks
//! the optimal `(cut, frequency)` per (device, channel) instant.  Real
//! edge deployments must learn cut placement online, under channel
//! dynamics the server cannot observe in closed form.  This module is
//! that learner: a [`LearnedPolicy`] trait (observe context → choose a
//! cut arm → receive the realized cost as reward) with three
//! deterministic implementations — epsilon-greedy, UCB1, and Gaussian
//! Thompson sampling — over a discretized context of
//! (uplink-CQI bucket, device class).
//!
//! ## Determinism contract
//!
//! Learned decisions are stateful, which is exactly what the engines'
//! purity contract (DESIGN.md §8) forbids *within* a round.  The
//! [`PolicyBank`] therefore freezes its statistics for the duration of
//! a round: every decision in round `n` reads state folded from rounds
//! `< n`, and the engines fold round `n`'s realized costs exactly once,
//! at the round boundary, in device order
//! ([`Scheduler::policy_observe`]).  Exploration randomness never
//! touches the cell's channel stream — each cell derives a dedicated
//! policy stream from `stream_root ^ POLICY_SALT`, so a learned run
//! realizes bit-identical links to the CARD run it is benchmarked
//! against, and stays bit-reproducible at any thread count.
//!
//! [`Scheduler::policy_observe`]: crate::coordinator::Scheduler::policy_observe

pub mod bandits;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::DeviceSpec;
use crate::net::cqi::cqi_for_snr;
use crate::obs;
use crate::util::rng::Rng;

pub use bandits::{ArmsView, EpsilonGreedy, GaussianThompson, LearnedPolicy, Ucb1};

/// Salt folded into the scheduler's stream root to derive per-cell
/// policy streams — a dedicated RNG domain, disjoint by construction
/// from the channel/mobility (`stream_root`), churn (`seed ^ 0xDE5C4`),
/// and fault (`seed ^ 0xFA0170`) domains, so exploration never perturbs
/// what any other subsystem draws.
pub const POLICY_SALT: u64 = 0xB0_11_C7;

/// Uplink-CQI buckets: the 16 CQI levels collapse 4:1.
pub const N_CQI_BUCKETS: usize = 4;

/// Device classes: fast/slow split at the fleet's geometric-mean
/// throughput.
pub const N_DEVICE_CLASSES: usize = 2;

/// Contexts = device class × CQI bucket.
pub const N_CONTEXTS: usize = N_DEVICE_CLASSES * N_CQI_BUCKETS;

/// Which bandit rule a [`PolicyBank`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    EpsGreedy,
    Ucb1,
    Thompson,
}

impl PolicyKind {
    /// The decision rule (stateless — all state lives in the bank).
    pub fn rule(&self) -> &'static dyn LearnedPolicy {
        static EPS: EpsilonGreedy = EpsilonGreedy { epsilon: 0.1 };
        static UCB: Ucb1 = Ucb1;
        static TS: GaussianThompson = GaussianThompson { sigma_floor: 0.05 };
        match self {
            PolicyKind::EpsGreedy => &EPS,
            PolicyKind::Ucb1 => &UCB,
            PolicyKind::Thompson => &TS,
        }
    }
}

/// One realized cell fed back to the bank at a round boundary: the
/// context coordinates, the cut the policy chose, and the realized
/// Eq.-12 cost (the reward signal, negated by convention — the bank
/// minimizes).
#[derive(Clone, Copy, Debug)]
pub struct PolicyObs {
    pub device_idx: usize,
    pub snr_up_db: f64,
    pub cut: usize,
    pub cost: f64,
}

/// Checkpointable copy of a bank's mutable state (`exp::checkpoint`
/// serializes this alongside the DES snapshot).
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyBankSnap {
    pub n_ctx: usize,
    pub n_arms: usize,
    /// per (ctx, arm): pull count
    pub count: Vec<u64>,
    /// per (ctx, arm): Welford running mean of the cost
    pub mean: Vec<f64>,
    /// per (ctx, arm): Welford M2 (sum of squared deviations)
    pub m2: Vec<f64>,
    /// per ctx: total pulls
    pub pulls: Vec<u64>,
    pub explore: u64,
    pub exploit: u64,
}

/// Map the realized uplink SNR to its context bucket.
#[inline]
pub fn cqi_bucket(snr_up_db: f64) -> usize {
    (cqi_for_snr(snr_up_db) as usize / 4).min(N_CQI_BUCKETS - 1)
}

/// Derive each device's class from its compute throughput: class 1
/// (fast) above the fleet's geometric-mean throughput, class 0 (slow)
/// at or below it.  A pure function of the config, so every engine and
/// thread count derives the identical partition; a homogeneous fleet
/// collapses to one class.
pub fn device_classes(devices: &[DeviceSpec]) -> Vec<u8> {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for d in devices {
        let t = d.throughput();
        lo = lo.min(t);
        hi = hi.max(t);
    }
    if !(hi > lo) {
        return vec![0; devices.len()];
    }
    let split = (lo * hi).sqrt();
    devices
        .iter()
        .map(|d| u8::from(d.throughput() > split))
        .collect()
}

/// The coarse cut grid the bandits choose from: 9 evenly spaced cuts
/// over `0..=n_layers` (deduplicated for shallow models).  A 33-arm
/// grid over every cut would take thousands of pulls per context to
/// converge; the coarse grid keeps the learning problem solvable at
/// fleet-sweep horizons while still spanning server-only (0) to
/// device-only (I).
pub fn arm_grid(n_layers: usize) -> Vec<usize> {
    let mut arms: Vec<usize> = (0..=8).map(|k| (k * n_layers + 4) / 8).collect();
    arms.dedup();
    arms
}

/// The contextual-bandit state behind a learned `Strategy`: per
/// (context, arm) Welford cost statistics, shared across the fleet
/// (devices pool their experience through the context discretization).
///
/// Reads ([`PolicyBank::choose_cut`]) take `&self` and are safe from
/// any thread *between* folds; writes ([`PolicyBank::observe`],
/// [`PolicyBank::reset`], [`PolicyBank::restore`]) require `&mut self`
/// and happen only at round boundaries, under the scheduler's lock.
#[derive(Debug)]
pub struct PolicyBank {
    kind: PolicyKind,
    /// The cut each arm index maps to (sorted, deduplicated).
    arms: Vec<usize>,
    /// Per-device class (derived once from the config).
    classes: Vec<u8>,
    count: Vec<u64>,
    mean: Vec<f64>,
    m2: Vec<f64>,
    pulls: Vec<u64>,
    /// Exploration/exploitation tallies — atomics because decisions run
    /// on pool workers under a read lock; totals are order-independent.
    explore: AtomicU64,
    exploit: AtomicU64,
}

impl PolicyBank {
    pub fn new(kind: PolicyKind, devices: &[DeviceSpec], n_layers: usize) -> Self {
        let arms = arm_grid(n_layers);
        let n = N_CONTEXTS * arms.len();
        PolicyBank {
            kind,
            arms,
            classes: device_classes(devices),
            count: vec![0; n],
            mean: vec![0.0; n],
            m2: vec![0.0; n],
            pulls: vec![0; N_CONTEXTS],
            explore: AtomicU64::new(0),
            exploit: AtomicU64::new(0),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        self.kind
    }

    /// The cut grid the bandit chooses from.
    pub fn arms(&self) -> &[usize] {
        &self.arms
    }

    /// Context index for one cell.
    #[inline]
    fn ctx(&self, device_idx: usize, snr_up_db: f64) -> usize {
        self.classes[device_idx] as usize * N_CQI_BUCKETS + cqi_bucket(snr_up_db)
    }

    /// Choose a cut for one cell from the frozen statistics.  `rng` must
    /// be the cell's dedicated policy stream — never the channel stream.
    pub fn choose_cut(&self, device_idx: usize, snr_up_db: f64, rng: &mut Rng) -> usize {
        let n_arms = self.arms.len();
        let base = self.ctx(device_idx, snr_up_db) * n_arms;
        let view = ArmsView {
            count: &self.count[base..base + n_arms],
            mean: &self.mean[base..base + n_arms],
            m2: &self.m2[base..base + n_arms],
            pulls: self.pulls[base / n_arms],
        };
        let arm = self.kind.rule().choose(&view, rng);
        debug_assert!(arm < n_arms);
        // exploration = any deviation from the pure-greedy argmin
        // (untried arms count as exploration); tallies observe only
        if view.greedy() == Some(arm) {
            self.exploit.fetch_add(1, Ordering::Relaxed);
            obs::metrics().policy_exploit.inc(device_idx);
        } else {
            self.explore.fetch_add(1, Ordering::Relaxed);
            obs::metrics().policy_explore.inc(device_idx);
        }
        self.arms[arm]
    }

    /// Fold one realized cell into the statistics (round boundary,
    /// device order — the engines guarantee the fold order).
    pub fn observe(&mut self, o: &PolicyObs) {
        let arm = self
            .arms
            .binary_search(&o.cut)
            .unwrap_or_else(|_| panic!("cut {} is not on the policy arm grid", o.cut));
        let ctx = self.ctx(o.device_idx, o.snr_up_db);
        let i = ctx * self.arms.len() + arm;
        self.pulls[ctx] += 1;
        self.count[i] += 1;
        let n = self.count[i] as f64;
        let delta = o.cost - self.mean[i];
        self.mean[i] += delta / n;
        self.m2[i] += delta * (o.cost - self.mean[i]);
    }

    /// `(explore, exploit)` decision tallies since the last reset.
    pub fn counters(&self) -> (u64, u64) {
        (
            self.explore.load(Ordering::Relaxed),
            self.exploit.load(Ordering::Relaxed),
        )
    }

    /// Forget everything — every `run*` entry point resets so repeated
    /// runs of one scheduler reproduce bit-identically.
    pub fn reset(&mut self) {
        self.count.fill(0);
        self.mean.fill(0.0);
        self.m2.fill(0.0);
        self.pulls.fill(0);
        self.explore.store(0, Ordering::Relaxed);
        self.exploit.store(0, Ordering::Relaxed);
    }

    /// Checkpointable copy of the mutable state.
    pub fn snapshot(&self) -> PolicyBankSnap {
        PolicyBankSnap {
            n_ctx: N_CONTEXTS,
            n_arms: self.arms.len(),
            count: self.count.clone(),
            mean: self.mean.clone(),
            m2: self.m2.clone(),
            pulls: self.pulls.clone(),
            explore: self.explore.load(Ordering::Relaxed),
            exploit: self.exploit.load(Ordering::Relaxed),
        }
    }

    /// Inverse of [`PolicyBank::snapshot`].
    pub fn restore(&mut self, snap: &PolicyBankSnap) -> anyhow::Result<()> {
        anyhow::ensure!(
            snap.n_ctx == N_CONTEXTS && snap.n_arms == self.arms.len(),
            "policy snapshot shape {}x{} does not fit this bank ({}x{})",
            snap.n_ctx,
            snap.n_arms,
            N_CONTEXTS,
            self.arms.len()
        );
        anyhow::ensure!(
            snap.count.len() == self.count.len()
                && snap.mean.len() == self.mean.len()
                && snap.m2.len() == self.m2.len()
                && snap.pulls.len() == self.pulls.len(),
            "policy snapshot vector lengths are inconsistent"
        );
        self.count.copy_from_slice(&snap.count);
        self.mean.copy_from_slice(&snap.mean);
        self.m2.copy_from_slice(&snap.m2);
        self.pulls.copy_from_slice(&snap.pulls);
        self.explore.store(snap.explore, Ordering::Relaxed);
        self.exploit.store(snap.exploit, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;

    fn bank(kind: PolicyKind) -> PolicyBank {
        PolicyBank::new(kind, &ExpConfig::paper().devices, 32)
    }

    #[test]
    fn arm_grid_spans_and_dedups() {
        assert_eq!(arm_grid(32), vec![0, 4, 8, 12, 16, 20, 24, 28, 32]);
        assert_eq!(arm_grid(4), vec![0, 1, 2, 3, 4]);
        let g = arm_grid(2);
        assert_eq!(g.first(), Some(&0));
        assert_eq!(g.last(), Some(&2));
        for w in g.windows(2) {
            assert!(w[0] < w[1], "grid must stay strictly increasing: {g:?}");
        }
    }

    #[test]
    fn device_classes_split_the_paper_fleet() {
        let cfg = ExpConfig::paper();
        let classes = device_classes(&cfg.devices);
        assert_eq!(classes.len(), cfg.devices.len());
        assert!(classes.contains(&0) && classes.contains(&1), "{classes:?}");
        // the paper fleet is strictly decreasing in capability, so the
        // class vector must be non-increasing
        for w in classes.windows(2) {
            assert!(w[0] >= w[1], "{classes:?}");
        }
    }

    #[test]
    fn homogeneous_fleet_collapses_to_one_class() {
        let cfg = ExpConfig::paper();
        let twin = vec![cfg.devices[0].clone(); 4];
        assert_eq!(device_classes(&twin), vec![0, 0, 0, 0]);
    }

    #[test]
    fn cqi_buckets_cover_the_range() {
        assert_eq!(cqi_bucket(-30.0), 0);
        assert_eq!(cqi_bucket(60.0), N_CQI_BUCKETS - 1);
        for snr in -30..60 {
            assert!(cqi_bucket(snr as f64) < N_CQI_BUCKETS);
        }
    }

    #[test]
    fn observe_accumulates_welford_stats() {
        let mut b = bank(PolicyKind::Ucb1);
        let cut = b.arms()[2];
        for (i, cost) in [0.2, 0.4, 0.6].iter().enumerate() {
            b.observe(&PolicyObs {
                device_idx: 0,
                snr_up_db: 10.0,
                cut,
                cost: *cost,
            });
            let snap = b.snapshot();
            let total: u64 = snap.count.iter().sum();
            assert_eq!(total, i as u64 + 1);
        }
        let snap = b.snapshot();
        let i = snap.count.iter().position(|&c| c == 3).unwrap();
        assert!((snap.mean[i] - 0.4).abs() < 1e-12);
        assert!((snap.m2[i] - 0.08).abs() < 1e-12);
    }

    #[test]
    fn choose_is_pure_given_frozen_stats_and_stream() {
        for kind in [PolicyKind::EpsGreedy, PolicyKind::Ucb1, PolicyKind::Thompson] {
            let b = bank(kind);
            for seed in 0..20u64 {
                let a = b.choose_cut(1, 12.0, &mut Rng::new(seed));
                let again = b.choose_cut(1, 12.0, &mut Rng::new(seed));
                assert_eq!(a, again, "{kind:?} seed {seed}");
                assert!(b.arms().contains(&a));
            }
        }
    }

    #[test]
    fn untried_arms_are_visited_first() {
        // with an empty bank every rule sweeps the arm grid in order
        for kind in [PolicyKind::EpsGreedy, PolicyKind::Ucb1, PolicyKind::Thompson] {
            let mut b = bank(kind);
            let mut seen = Vec::new();
            for _ in 0..b.arms().len() {
                let cut = b.choose_cut(0, 10.0, &mut Rng::new(7));
                seen.push(cut);
                b.observe(&PolicyObs {
                    device_idx: 0,
                    snr_up_db: 10.0,
                    cut,
                    cost: 0.5,
                });
            }
            assert_eq!(seen, b.arms(), "{kind:?} must try every arm once");
        }
    }

    #[test]
    fn snapshot_restore_round_trips() {
        let mut b = bank(PolicyKind::Thompson);
        for r in 0..40 {
            let cut = b.choose_cut(r % 5, (r % 30) as f64, &mut Rng::new(r as u64));
            b.observe(&PolicyObs {
                device_idx: r % 5,
                snr_up_db: (r % 30) as f64,
                cut,
                cost: 0.1 + 0.01 * r as f64,
            });
        }
        let snap = b.snapshot();
        let mut c = bank(PolicyKind::Thompson);
        c.restore(&snap).unwrap();
        assert_eq!(c.snapshot(), snap);
        // restore rejects a foreign shape
        let mut bad = snap.clone();
        bad.n_arms += 1;
        assert!(c.restore(&bad).is_err());
    }

    #[test]
    fn reset_clears_everything() {
        let mut b = bank(PolicyKind::EpsGreedy);
        let cut = b.choose_cut(0, 10.0, &mut Rng::new(1));
        b.observe(&PolicyObs {
            device_idx: 0,
            snr_up_db: 10.0,
            cut,
            cost: 0.3,
        });
        b.reset();
        let snap = b.snapshot();
        assert!(snap.count.iter().all(|&c| c == 0));
        assert!(snap.pulls.iter().all(|&p| p == 0));
        assert_eq!(b.counters(), (0, 0));
    }
}
