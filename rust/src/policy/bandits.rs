//! The bandit decision rules: deterministic functions of (frozen arm
//! statistics, a dedicated counter-based RNG stream).
//!
//! All three rules *minimize* — arm statistics are realized Eq.-12
//! costs, so lower is better — and share two conventions that make the
//! whole subsystem reproducible:
//!
//! 1. **Untried arms first.**  While any arm in the context has zero
//!    pulls, every rule plays the lowest-index untried arm.  This makes
//!    the cold-start phase a deterministic sweep of the grid (no RNG
//!    consumed), identical for every rule and thread count.
//! 2. **Lowest-index tie-break.**  Score ties resolve to the smaller
//!    arm index, never to RNG state.

use crate::util::rng::Rng;

/// Frozen per-context statistics handed to a rule: parallel slices over
/// the arms of one context, plus the context's total pull count.
pub struct ArmsView<'a> {
    /// pulls per arm
    pub count: &'a [u64],
    /// Welford running mean cost per arm
    pub mean: &'a [f64],
    /// Welford M2 (sum of squared deviations) per arm
    pub m2: &'a [f64],
    /// total pulls in this context (= `count.iter().sum()`)
    pub pulls: u64,
}

impl ArmsView<'_> {
    /// Lowest-index untried arm, if any.
    pub fn untried(&self) -> Option<usize> {
        self.count.iter().position(|&c| c == 0)
    }

    /// The pure-greedy choice: argmin of the empirical means, lowest
    /// index on ties; `None` while any arm is untried (greedy is not
    /// meaningful on an incomplete sweep).
    pub fn greedy(&self) -> Option<usize> {
        if self.untried().is_some() {
            return None;
        }
        argmin(self.mean.iter().copied())
    }

    /// Unbiased sample standard deviation of one arm, floored.
    pub fn stddev(&self, arm: usize, floor: f64) -> f64 {
        if self.count[arm] < 2 {
            return floor;
        }
        (self.m2[arm] / (self.count[arm] - 1) as f64).sqrt().max(floor)
    }
}

/// First argmin of a score sequence (lowest index wins ties).
fn argmin(scores: impl Iterator<Item = f64>) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.enumerate() {
        match best {
            Some((_, b)) if s >= b => {}
            _ => best = Some((i, s)),
        }
    }
    best.map(|(i, _)| i)
}

/// A contextual-bandit decision rule: observe the frozen context
/// statistics, choose an arm, and (through the engines) receive the
/// realized cost as reward at the round boundary.
pub trait LearnedPolicy: Sync {
    /// Stable identifier used in reports and metric keys.
    fn name(&self) -> &'static str;

    /// Pick an arm index.  Must be a pure function of `(view, rng)` —
    /// no interior mutability, no ambient state.
    fn choose(&self, view: &ArmsView, rng: &mut Rng) -> usize;
}

/// ε-greedy: with probability ε pick a uniform arm, otherwise the
/// empirical argmin.  The classic myopic baseline the confidence-based
/// rules are expected to beat on correlated channels.
#[derive(Clone, Copy, Debug)]
pub struct EpsilonGreedy {
    pub epsilon: f64,
}

impl LearnedPolicy for EpsilonGreedy {
    fn name(&self) -> &'static str {
        "eps-greedy"
    }

    fn choose(&self, view: &ArmsView, rng: &mut Rng) -> usize {
        if let Some(a) = view.untried() {
            return a;
        }
        if rng.f64() < self.epsilon {
            rng.below(view.count.len() as u64) as usize
        } else {
            argmin(view.mean.iter().copied()).expect("non-empty arm grid")
        }
    }
}

/// UCB1 (lower-confidence bound, since we minimize): score each arm
/// `mean − sqrt(ln t / 128n)` and play the argmin.  The radius keeps
/// the Hoeffding shape but is deliberately tight: Eq.-12 costs over the
/// cut grid live in a band far narrower than the worst-case [0, 1]
/// range, and the classic `2/n` (or even `1/2n`) radius over-explores
/// near-tied cuts for the whole fleet-sweep horizon instead of
/// converging.  `1/128n` resolves the grid within a few hundred rounds
/// while still pre-empting any arm whose count lags far behind.
#[derive(Clone, Copy, Debug)]
pub struct Ucb1;

impl LearnedPolicy for Ucb1 {
    fn name(&self) -> &'static str {
        "ucb1"
    }

    fn choose(&self, view: &ArmsView, _rng: &mut Rng) -> usize {
        if let Some(a) = view.untried() {
            return a;
        }
        let ln_t = (view.pulls.max(1) as f64).ln();
        argmin((0..view.count.len()).map(|a| {
            view.mean[a] - (ln_t / (128.0 * view.count[a] as f64)).sqrt()
        }))
        .expect("non-empty arm grid")
    }
}

/// Gaussian Thompson sampling: draw `mean + N(0,1)·s/sqrt(n)` per arm
/// (s = sample stddev, floored so a low-variance arm keeps exploring)
/// and play the argmin draw.  Posterior-shaped exploration — arms with
/// uncertain means get sampled optimistically often enough to resolve
/// them, without UCB's uniform radius.
#[derive(Clone, Copy, Debug)]
pub struct GaussianThompson {
    pub sigma_floor: f64,
}

impl LearnedPolicy for GaussianThompson {
    fn name(&self) -> &'static str {
        "thompson"
    }

    fn choose(&self, view: &ArmsView, rng: &mut Rng) -> usize {
        if let Some(a) = view.untried() {
            return a;
        }
        argmin((0..view.count.len()).map(|a| {
            let se = view.stddev(a, self.sigma_floor) / (view.count[a] as f64).sqrt();
            view.mean[a] + rng.gauss() * se
        }))
        .expect("non-empty arm grid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 4-arm view where arm 2 is clearly best (mean 0.1 vs 0.5+).
    fn converged<'a>(
        count: &'a [u64; 4],
        mean: &'a [f64; 4],
        m2: &'a [f64; 4],
    ) -> ArmsView<'a> {
        ArmsView {
            count,
            mean,
            m2,
            pulls: count.iter().sum(),
        }
    }

    const COUNT: [u64; 4] = [50, 50, 50, 50];
    const MEAN: [f64; 4] = [0.5, 0.6, 0.1, 0.7];
    const M2: [f64; 4] = [0.5, 0.5, 0.5, 0.5];

    #[test]
    fn argmin_prefers_lowest_index_on_ties() {
        assert_eq!(argmin([1.0, 0.5, 0.5, 2.0].into_iter()), Some(1));
        assert_eq!(argmin(std::iter::empty()), None);
    }

    #[test]
    fn untried_arms_preempt_every_rule() {
        let count = [3, 0, 5, 0];
        let view = ArmsView {
            count: &count,
            mean: &MEAN,
            m2: &M2,
            pulls: 8,
        };
        let mut rng = Rng::new(1);
        assert_eq!(EpsilonGreedy { epsilon: 0.1 }.choose(&view, &mut rng), 1);
        assert_eq!(Ucb1.choose(&view, &mut rng), 1);
        assert_eq!(GaussianThompson { sigma_floor: 0.05 }.choose(&view, &mut rng), 1);
        assert_eq!(view.greedy(), None);
    }

    #[test]
    fn eps_greedy_mostly_exploits_the_best_arm() {
        let view = converged(&COUNT, &MEAN, &M2);
        let rule = EpsilonGreedy { epsilon: 0.1 };
        let mut rng = Rng::new(42);
        let picks: Vec<usize> = (0..1000).map(|_| rule.choose(&view, &mut rng)).collect();
        let best = picks.iter().filter(|&&a| a == 2).count();
        assert!(best > 850, "greedy share too low: {best}/1000");
        // but it does explore
        assert!(picks.iter().any(|&a| a != 2));
    }

    #[test]
    fn ucb_converges_to_the_best_arm_and_ignores_rng() {
        let view = converged(&COUNT, &MEAN, &M2);
        let mut a = Rng::new(1);
        let mut b = Rng::new(999);
        assert_eq!(Ucb1.choose(&view, &mut a), 2);
        assert_eq!(Ucb1.choose(&view, &mut b), 2);
        // rng untouched: both streams still agree on their next draw
        assert_eq!(Rng::new(1).f64(), a.f64());
    }

    #[test]
    fn ucb_bonus_favors_undersampled_arms() {
        // arm 2 is best on mean but heavily sampled; arm 0 has a huge
        // confidence radius with only 1 pull and a near-tied mean
        let count = [1, 400, 400, 400];
        let mean = [0.15, 0.6, 0.1, 0.7];
        let view = converged(&count, &mean, &M2);
        assert_eq!(Ucb1.choose(&view, &mut Rng::new(0)), 0);
    }

    #[test]
    fn thompson_samples_around_the_posterior() {
        let view = converged(&COUNT, &MEAN, &M2);
        let rule = GaussianThompson { sigma_floor: 0.05 };
        let mut rng = Rng::new(7);
        let picks: Vec<usize> = (0..1000).map(|_| rule.choose(&view, &mut rng)).collect();
        let best = picks.iter().filter(|&&a| a == 2).count();
        assert!(best > 900, "posterior share too low: {best}/1000");
    }

    #[test]
    fn rules_are_deterministic_per_stream() {
        let view = converged(&COUNT, &MEAN, &M2);
        for seed in 0..10u64 {
            for rule in [
                &EpsilonGreedy { epsilon: 0.3 } as &dyn LearnedPolicy,
                &Ucb1,
                &GaussianThompson { sigma_floor: 0.05 },
            ] {
                let x = rule.choose(&view, &mut Rng::new(seed));
                let y = rule.choose(&view, &mut Rng::new(seed));
                assert_eq!(x, y, "{} seed {seed}", rule.name());
            }
        }
    }

    #[test]
    fn stddev_floors_small_samples() {
        let count = [1, 2, 50, 50];
        let view = converged(&count, &MEAN, &M2);
        assert_eq!(view.stddev(0, 0.05), 0.05);
        assert!(view.stddev(2, 0.05) > 0.05);
    }
}
