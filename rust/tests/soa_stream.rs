//! Acceptance gate for the streaming SoA round engine (DESIGN.md §18):
//! the `ExecMode::Cached` path — bounded `RoundBatch` windows, pool-
//! chunked column fills, lazy name resolution — must be **bitwise
//! identical** to both retained AoS oracles (`run_uncached`, `run_ref`)
//! on every scenario preset, under every decision strategy, at every
//! thread count.  The per-cell purity argument (counter-based RNG
//! streams) says any chunking is invisible; this suite is the proof.

use edgesplit::config::scenario;
use edgesplit::coordinator::{Strategy, SOA_CHUNK, SOA_WINDOW};
use edgesplit::exp::{verify, ExperimentBuilder};

const SEED: u64 = 23;

fn gate(preset: &str, strategy: Strategy, devices: usize, rounds: usize, threads: usize) {
    let exp = ExperimentBuilder::preset(preset)
        .devices(devices)
        .rounds(rounds)
        .seed(SEED)
        .threads(threads)
        .strategy(strategy)
        .build()
        .unwrap_or_else(|e| panic!("{preset}: build failed: {e}"));
    verify::verify_soa_matches_oracles(&exp).unwrap_or_else(|e| {
        panic!(
            "{preset} / {} / {threads} thread(s): SoA stream diverged from an oracle: {e:#}",
            strategy.name()
        )
    });
}

/// Every preset × strategy × thread count, small fleets: the full
/// cross-product the acceptance spec names.
#[test]
fn soa_stream_matches_oracles_on_every_preset_strategy_and_thread_count() {
    let strategies = [
        Strategy::Card,
        Strategy::ServerOnly,
        Strategy::DeviceOnly,
        Strategy::StaticCut(5),
        Strategy::RandomCut,
    ];
    for sc in &scenario::ALL {
        for &strategy in &strategies {
            for threads in [1, 2, 8] {
                gate(sc.name, strategy, 9, 3, threads);
            }
        }
    }
}

/// A fleet larger than one SoA chunk forces the pooled fill to span
/// multiple chunks within a window.
#[test]
fn soa_stream_survives_multi_chunk_windows() {
    for sc in &scenario::ALL {
        gate(sc.name, Strategy::Card, SOA_CHUNK + 13, 2, 8);
    }
}

/// A fleet larger than one SoA *window* forces the engine's outer
/// streaming loop to emit multiple (and one partial) windows — the
/// window boundary must be invisible in the record stream.
#[test]
fn soa_stream_survives_multi_window_fleets() {
    gate(scenario::DENSE_URBAN.name, Strategy::Card, SOA_WINDOW + 37, 1, 8);
}
