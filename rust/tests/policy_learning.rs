//! Property suite for the online-learning cut policies (DESIGN.md §19):
//! regret quality vs the CARD oracle, bit-determinism across thread
//! counts and checkpoint/resume, channel isolation, and the
//! decision-cache guard for every uncacheable strategy.

use std::sync::Arc;

use edgesplit::config::scenario;
use edgesplit::coordinator::{Scheduler, Strategy};
use edgesplit::des::{DesConfig, DesEngine, Policy};
use edgesplit::exp::verify::{
    verify_bit_identical, verify_checkpoint_resume_bit_identity_with,
    verify_learned_channel_isolation, verify_learned_thread_determinism,
};
use edgesplit::exp::{EngineChoice, ExperimentBuilder};
use edgesplit::sim::policysweep;
use edgesplit::util::benchkit::Bencher;

const LEARNED: [Strategy; 3] = [Strategy::EpsGreedy, Strategy::Ucb1, Strategy::Thompson];

/// The acceptance horizon: enough pulls per (context, arm) for UCB's
/// confidence radii to separate the arms on every preset.
const FLEET: usize = 24;
const HORIZON: usize = 300;

fn regret_sweep(sc: scenario::Scenario) -> policysweep::PolicySweep {
    let mut bench = Bencher::new("policy-test");
    policysweep::sweep(
        &[sc],
        FLEET,
        Some(HORIZON),
        2,
        7,
        false,
        &mut bench,
    )
    .unwrap()
}

fn assert_learned_beat_unlearned(sweep: &policysweep::PolicySweep, scenario: &str) {
    let final_of = |key: &str| sweep.curve(scenario, key).unwrap().final_regret;
    let (eps, random) = (final_of("eps-greedy"), final_of("random-cut"));
    for smart in ["ucb1", "thompson"] {
        let r = final_of(smart);
        assert!(
            r < eps,
            "{scenario}: {smart} regret {r} should beat eps-greedy {eps}"
        );
        assert!(
            r < random,
            "{scenario}: {smart} regret {r} should beat random {random}"
        );
    }
    assert_eq!(final_of("card"), 0.0, "{scenario}: CARD self-regret");
}

fn assert_sublinear(sweep: &policysweep::PolicySweep, scenario: &str) {
    for key in ["ucb1", "thompson"] {
        let c = &sweep.curve(scenario, key).unwrap().cumulative_regret;
        let (half, full) = (c[c.len() / 2 - 1], *c.last().unwrap());
        // a linear curve doubles over the second half; a converged
        // bandit adds much less than it did while exploring
        assert!(
            full - half < 0.8 * half,
            "{scenario}: {key} regret not sublinear (half {half}, full {full})"
        );
        assert!(full > 0.0, "{scenario}: {key} never explored at all");
    }
}

#[test]
fn ucb_and_thompson_beat_eps_greedy_and_random_on_correlated_indoor() {
    let sweep = regret_sweep(scenario::CORRELATED_INDOOR);
    assert_learned_beat_unlearned(&sweep, "correlated-indoor");
    assert_sublinear(&sweep, "correlated-indoor");
}

#[test]
fn ucb_and_thompson_beat_eps_greedy_and_random_on_mobile_vehicular() {
    let sweep = regret_sweep(scenario::MOBILE_VEHICULAR);
    assert_learned_beat_unlearned(&sweep, "mobile-vehicular");
    assert_sublinear(&sweep, "mobile-vehicular");
}

#[test]
fn learned_streams_bit_identical_across_thread_counts_and_seeds() {
    for seed in [1u64, 7, 23] {
        let mut cfg = scenario::CORRELATED_INDOOR.config(10, seed).unwrap();
        cfg.workload.rounds = 12;
        for strategy in LEARNED {
            // serial vs 2 and 8 workers
            verify_learned_thread_determinism(&cfg, scenario::CORRELATED_INDOOR.state, strategy)
                .unwrap();
            // and a degenerate 1-worker parallel run
            let sched = Scheduler::new(cfg.clone(), scenario::CORRELATED_INDOOR.state, strategy);
            let serial = sched.run_analytic().unwrap();
            verify_bit_identical(&serial, &sched.run_parallel(1)).unwrap();
        }
    }
}

#[test]
fn learned_runs_never_perturb_the_channel() {
    for sc in [scenario::CORRELATED_INDOOR, scenario::MOBILE_VEHICULAR] {
        let mut cfg = sc.config(8, 5).unwrap();
        cfg.workload.rounds = 10;
        for strategy in LEARNED {
            verify_learned_channel_isolation(&cfg, sc.state, strategy).unwrap();
        }
    }
}

#[test]
fn des_checkpoint_resume_is_bit_identical_for_learned_strategies() {
    let des = DesConfig {
        policy: Policy::Sync,
        capacity: 2,
        batch: 1,
    };
    for seed in [1u64, 7, 23] {
        let mut cfg = scenario::DENSE_URBAN.config(6, seed).unwrap();
        cfg.workload.rounds = 5;
        for strategy in LEARNED {
            // freeze early (mid-learning) and late (mostly replayed)
            for t_s in [0.5, 4.0] {
                verify_checkpoint_resume_bit_identity_with(
                    &cfg,
                    scenario::DENSE_URBAN.state,
                    des,
                    t_s,
                    strategy,
                )
                .unwrap_or_else(|e| {
                    panic!("{} seed {seed} t={t_s}: {e:#}", strategy.name())
                });
            }
        }
    }
}

#[test]
fn sync_churn_free_des_matches_round_engine_for_learned_strategies() {
    let mut cfg = scenario::CORRELATED_INDOOR.config(8, 11).unwrap();
    cfg.workload.rounds = 6;
    cfg.churn = Default::default();
    for strategy in LEARNED {
        let sched = Arc::new(Scheduler::new(
            cfg.clone(),
            scenario::CORRELATED_INDOOR.state,
            strategy,
        ));
        let out = DesEngine::new(
            sched.clone(),
            DesConfig {
                policy: Policy::Sync,
                capacity: 3,
                batch: 1,
            },
        )
        .run();
        let des_records: Vec<_> = out.records.iter().map(|r| r.record.clone()).collect();
        let serial = sched.run_analytic().unwrap();
        verify_bit_identical(&serial, &des_records)
            .unwrap_or_else(|e| panic!("{}: {e:#}", strategy.name()));
    }
}

#[test]
fn uncacheable_strategies_never_touch_the_decision_cache() {
    let uncacheable = [
        Strategy::RandomCut,
        Strategy::EpsGreedy,
        Strategy::Ucb1,
        Strategy::Thompson,
    ];
    let mut cfg = scenario::DENSE_URBAN.config(6, 3).unwrap();
    cfg.workload.rounds = 4;
    for strategy in uncacheable {
        assert!(!strategy.cacheable());
        // every scheduler-level path on one instance
        let sched = Scheduler::new(cfg.clone(), scenario::DENSE_URBAN.state, strategy);
        sched.run_analytic().unwrap();
        sched.run_parallel(4);
        sched.run_uncached();
        sched.run_ref();
        assert_eq!(
            sched.cache_stats(),
            (0, 0),
            "{}: scheduler paths touched the cache",
            strategy.name()
        );
        // the streaming round engine
        let exp = ExperimentBuilder::from_config(cfg.clone())
            .channel_state(scenario::DENSE_URBAN.state)
            .strategy(strategy)
            .build()
            .unwrap();
        exp.run_collect().unwrap();
        assert_eq!(
            exp.scheduler().cache_stats(),
            (0, 0),
            "{}: round engine touched the cache",
            strategy.name()
        );
        // the event engine
        let exp = ExperimentBuilder::from_config(cfg.clone())
            .channel_state(scenario::DENSE_URBAN.state)
            .strategy(strategy)
            .engine(EngineChoice::Des(DesConfig {
                policy: Policy::Sync,
                capacity: 2,
                batch: 1,
            }))
            .build()
            .unwrap();
        exp.run_collect().unwrap();
        assert_eq!(
            exp.scheduler().cache_stats(),
            (0, 0),
            "{}: event engine touched the cache",
            strategy.name()
        );
    }
}

#[test]
fn soa_stream_matches_oracles_under_learned_strategies() {
    let mut cfg = scenario::MOBILE_VEHICULAR.config(7, 9).unwrap();
    cfg.workload.rounds = 6;
    for strategy in LEARNED {
        let exp = ExperimentBuilder::from_config(cfg.clone())
            .channel_state(scenario::MOBILE_VEHICULAR.state)
            .strategy(strategy)
            .build()
            .unwrap();
        edgesplit::exp::verify::verify_soa_matches_oracles(&exp)
            .unwrap_or_else(|e| panic!("{}: {e:#}", strategy.name()));
    }
}
