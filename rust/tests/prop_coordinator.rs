//! Property-based tests on coordinator invariants (homegrown kit —
//! util::proptest; no proptest crate offline).
//!
//! Each property samples random devices / channels / weights / workloads
//! and checks a structural invariant of the paper's optimization.

use edgesplit::config::{DeviceSpec, ExpConfig, WorkloadSpec};
use edgesplit::coordinator::{Card, CostModel, Strategy};
use edgesplit::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LinkRates, LlmArch};
use edgesplit::prop_assert;
use edgesplit::util::proptest::{forall, PropConfig};
use edgesplit::util::rng::Rng;

#[derive(Debug)]
struct Scenario {
    dev: DeviceSpec,
    rates: LinkRates,
    w: f64,
    epochs: usize,
    phi: f64,
}

fn gen_scenario(r: &mut Rng) -> Scenario {
    Scenario {
        dev: DeviceSpec {
            name: "prop-dev".into(),
            platform: "synthetic".into(),
            freq_hz: r.range(0.2e9, 1.4e9),
            cores: [256.0, 512.0, 1024.0, 2048.0][r.below(4) as usize],
            flops_per_cycle: 2.0,
            distance_m: r.range(5.0, 45.0),
        },
        rates: LinkRates {
            up_bps: r.range(3e5, 8e8),
            down_bps: r.range(3e5, 8e8),
        },
        w: r.range(0.01, 0.99),
        epochs: 1 + r.below(8) as usize,
        phi: r.range(0.02, 1.0),
    }
}

fn cost_model(s: &Scenario) -> (CostModel, ExpConfig) {
    let mut cfg = ExpConfig::paper();
    cfg.card.w = s.w;
    cfg.workload = WorkloadSpec {
        local_epochs: s.epochs,
        phi: s.phi,
        ..WorkloadSpec::default()
    };
    let arch = LlmArch::llama1b();
    let fl = FlopModel::new(&arch, &cfg.workload);
    let cm = CostModel::new(
        DelayModel::new(fl.clone(), DataSizeModel::new(&arch, &cfg.workload), &cfg.workload),
        EnergyModel::new(fl, cfg.workload.local_epochs),
        s.w,
    );
    (cm, cfg)
}

#[test]
fn prop_decision_always_feasible() {
    forall(
        "CARD decision within constraint set",
        PropConfig::default(),
        gen_scenario,
        |s| {
            let (cm, cfg) = cost_model(s);
            let card = Card::new(&cm, &cfg.server);
            let d = card.decide(&s.dev, s.rates);
            prop_assert!(d.cut <= cm.n_layers(), "cut {} > I", d.cut);
            let f_min = s.dev.server_freq_floor(&cfg.server);
            prop_assert!(
                d.freq_hz >= f_min - 1.0 && d.freq_hz <= cfg.server.max_freq_hz + 1.0,
                "f {} outside [{}, {}]",
                d.freq_hz,
                f_min,
                cfg.server.max_freq_hz
            );
            prop_assert!(
                d.cost.is_finite() && d.delay_s > 0.0 && d.energy_j >= 0.0,
                "degenerate decision {d:?}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_card_beats_every_sampled_alternative() {
    // CARD's (c*, f*) must have U ≤ U(c, f) for ANY sampled feasible (c, f).
    forall(
        "CARD global optimality over random alternatives",
        PropConfig {
            seed: 0xCAFE,
            cases: 128,
        },
        |r| {
            let s = gen_scenario(r);
            let alt_cut = r.below(33) as usize;
            let alt_t = r.f64();
            (s, alt_cut, alt_t)
        },
        |(s, alt_cut, alt_t)| {
            let (cm, cfg) = cost_model(s);
            let card = Card::new(&cm, &cfg.server);
            let b = cm.bounds(&s.dev, &cfg.server, s.rates);
            let d = card.decide(&s.dev, s.rates);
            let f_min = s.dev.server_freq_floor(&cfg.server);
            let alt_f = f_min + alt_t * (cfg.server.max_freq_hz - f_min);
            let alt_u = cm.cost(*alt_cut, alt_f, &s.dev, &cfg.server, s.rates, &b);
            prop_assert!(
                d.cost <= alt_u + 1e-9,
                "CARD U={} beaten by (c={alt_cut}, f={alt_f:.3e}) U={alt_u}",
                d.cost
            );
            Ok(())
        },
    );
}

#[test]
fn prop_compute_delay_monotone_in_cut() {
    // The server out-computes every device (F_min assumption), so moving
    // a layer to the device can only increase total compute delay at
    // fixed server frequency.
    forall(
        "delay monotone in cut",
        PropConfig::default(),
        gen_scenario,
        |s| {
            let (cm, cfg) = cost_model(s);
            let f = cfg.server.max_freq_hz;
            let mut prev = -1.0f64;
            for c in 0..=cm.n_layers() {
                let d = cm.delay.compute(c, &s.dev, &cfg.server, f);
                prop_assert!(d >= prev - 1e-12, "compute delay dipped at c={c}");
                prev = d;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_strictly_decreasing_in_cut() {
    forall(
        "server energy decreasing in cut",
        PropConfig::default(),
        gen_scenario,
        |s| {
            let (cm, cfg) = cost_model(s);
            let f = 1.5e9;
            let mut prev = f64::INFINITY;
            for c in 0..=cm.n_layers() {
                let e = cm.energy.round(c, &cfg.server, f);
                prop_assert!(e < prev, "energy not decreasing at c={c}");
                prev = e;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cost_normalized_at_corners() {
    // U at the two paper corners equals (1-w) and w exactly.
    forall(
        "U corner normalization",
        PropConfig::default(),
        gen_scenario,
        |s| {
            let (cm, cfg) = cost_model(s);
            let b = cm.bounds(&s.dev, &cfg.server, s.rates);
            let i = cm.n_layers();
            let u_fast = cm.cost(0, cfg.server.max_freq_hz, &s.dev, &cfg.server, s.rates, &b);
            let u_slow = cm.cost(
                i,
                s.dev.server_freq_floor(&cfg.server),
                &s.dev,
                &cfg.server,
                s.rates,
                &b,
            );
            prop_assert!(
                (u_fast - (1.0 - s.w)).abs() < 1e-6,
                "corner0 {} != 1-w",
                u_fast
            );
            prop_assert!((u_slow - s.w).abs() < 1e-6, "cornerI {} != w", u_slow);
            Ok(())
        },
    );
}

#[test]
fn prop_strategies_feasible_and_ordered() {
    // Baselines always feasible; CARD never worse in U; device-only
    // minimizes server energy among the three.
    forall(
        "baseline orderings",
        PropConfig {
            seed: 0xBEEF,
            cases: 128,
        },
        gen_scenario,
        |s| {
            let (cm, cfg) = cost_model(s);
            let mut rng = Rng::new(1);
            let card = Strategy::Card.decide(&cm, &cfg.server, &s.dev, s.rates, &mut rng);
            let donly = Strategy::DeviceOnly.decide(&cm, &cfg.server, &s.dev, s.rates, &mut rng);
            let sonly = Strategy::ServerOnly.decide(&cm, &cfg.server, &s.dev, s.rates, &mut rng);
            prop_assert!(card.cost <= donly.cost + 1e-9, "CARD worse than device-only");
            prop_assert!(card.cost <= sonly.cost + 1e-9, "CARD worse than server-only");
            prop_assert!(
                donly.energy_j <= sonly.energy_j + 1e-9,
                "device-only should minimize server energy"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_rate_monotone_in_snr() {
    use edgesplit::net::spectral_efficiency;
    forall(
        "CQI efficiency monotone",
        PropConfig::default(),
        |r| (r.range(-30.0, 50.0), r.range(0.0, 10.0)),
        |&(snr, delta)| {
            let lo = spectral_efficiency(snr);
            let hi = spectral_efficiency(snr + delta);
            prop_assert!(hi >= lo, "efficiency dropped with SNR: {lo} -> {hi}");
            Ok(())
        },
    );
}

#[test]
fn prop_bounds_bracket_realized_costs() {
    // Any feasible decision's delay/energy lies within the paper's
    // normalization corners.
    forall(
        "bounds bracket realized values",
        PropConfig::default(),
        |r| {
            let s = gen_scenario(r);
            let c = r.below(33) as usize;
            let t = r.f64();
            (s, c, t)
        },
        |(s, c, t)| {
            let (cm, cfg) = cost_model(s);
            let b = cm.bounds(&s.dev, &cfg.server, s.rates);
            let f_min = s.dev.server_freq_floor(&cfg.server);
            let f = f_min + t * (cfg.server.max_freq_hz - f_min);
            let (d, e) = cm.delay_energy(*c, f, &s.dev, &cfg.server, s.rates);
            prop_assert!(d <= b.d_max + 1e-9, "delay {d} above D_max {}", b.d_max);
            prop_assert!(d >= b.d_min - 1e-9, "delay {d} below D_min {}", b.d_min);
            prop_assert!(e <= b.e_max + 1e-9, "energy {e} above E_max {}", b.e_max);
            prop_assert!(e >= b.e_min - 1e-9, "energy {e} below E_min {}", b.e_min);
            Ok(())
        },
    );
}

#[test]
fn prop_aggregator_out_of_order_merges_stay_consistent_and_bounded() {
    // DES async invariant: distribute a lease per device, then let the
    // merges land in *shuffled event order* via the unordered path.
    // After every merge, staleness relative to the newest version must
    // be bounded and monotonically non-increasing (merges only advance
    // layer versions), and once every lease has merged the adapter
    // stack is consistent at the server again.
    use edgesplit::coordinator::Aggregator;
    forall(
        "aggregator out-of-order merge invariants",
        PropConfig {
            seed: 0xA66_000D,
            cases: 200,
        },
        |r| {
            let n_devices = 1 + r.below(12) as usize;
            // device d holds a lease over [0, cuts[d]) based on version d+1
            let cuts: Vec<usize> = (0..n_devices).map(|_| r.below(33) as usize).collect();
            let mut order: Vec<usize> = (0..n_devices).collect();
            r.shuffle(&mut order);
            (cuts, order)
        },
        |(cuts, order)| {
            let mut agg = Aggregator::new(32);
            let newest = cuts.len(); // highest version any merge carries
            for (d, &c) in cuts.iter().enumerate() {
                agg.distribute(d, c, d + 1, c as f64);
            }
            let mut prev = usize::MAX;
            for &d in order {
                agg.merge_unordered(d, cuts[d], d + 1, cuts[d] as f64);
                let s = agg.staleness(newest);
                prop_assert!(s <= newest, "staleness {s} above bound {newest}");
                prop_assert!(
                    s <= prev,
                    "staleness increased across merges: {prev} -> {s}"
                );
                prev = s;
            }
            prop_assert!(
                agg.is_consistent(),
                "stack inconsistent after all shuffled merges: cuts {cuts:?} order {order:?}"
            );
            prop_assert!(
                agg.merges() == cuts.len() as u64,
                "merge count {} != {}",
                agg.merges(),
                cuts.len()
            );
            Ok(())
        },
    );
}

#[test]
fn prop_aggregator_roundtrip_any_cut_sequence() {
    use edgesplit::coordinator::Aggregator;
    forall(
        "aggregator consistency under random cut sequences",
        PropConfig::default(),
        |r| {
            let n_rounds = 1 + r.below(10) as usize;
            let cuts: Vec<usize> = (0..n_rounds).map(|_| r.below(33) as usize).collect();
            let devices: Vec<usize> = (0..n_rounds).map(|_| r.below(5) as usize).collect();
            (cuts, devices)
        },
        |(cuts, devices)| {
            let mut agg = Aggregator::new(32);
            for (round, (&c, &d)) in cuts.iter().zip(devices).enumerate() {
                agg.distribute(d, c, round, c as f64);
                agg.server_update(c, round);
                agg.merge(d, c, round, c as f64);
                prop_assert!(agg.is_consistent(), "inconsistent after round {round}");
            }
            prop_assert!(
                agg.merges() == cuts.len() as u64,
                "merge count {} != {}",
                agg.merges(),
                cuts.len()
            );
            Ok(())
        },
    );
}
