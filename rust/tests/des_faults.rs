//! Acceptance tests for the fault-injection subsystem (DESIGN.md §17)
//! through the public experiment API: retry accounting, timeout
//! demotion, graceful degradation, and checkpoint/resume bit-identity
//! through the versioned text envelope.

use edgesplit::config::FaultsSpec;
use edgesplit::des::{DesConfig, Policy, RunState};
use edgesplit::exp::{checkpoint, CollectSink, Experiment, ExperimentBuilder, NullSink};

fn faulty(
    spec: FaultsSpec,
    policy: Policy,
    capacity: usize,
    devices: usize,
    rounds: usize,
    seed: u64,
) -> Experiment {
    ExperimentBuilder::preset("dense-urban")
        .devices(devices)
        .rounds(rounds)
        .seed(seed)
        .faults(spec)
        .des(DesConfig {
            policy,
            capacity,
            batch: 1,
        })
        .build()
        .unwrap()
}

#[test]
fn link_outages_book_retries_and_waste_energy() {
    let spec = FaultsSpec {
        link_outage_rate_hz: 10.0,
        ..Default::default()
    };
    let exp = faulty(spec, Policy::Sync, 4, 6, 3, 7);
    let mut sink = NullSink;
    let des = exp.run_into(&mut sink).unwrap().des.unwrap();
    assert!(des.retries > 0, "rate 10 Hz must interrupt some transfer");
    assert!(
        des.retry_energy_j > 0.0,
        "interrupted partial transfers must be billed"
    );
    // the retry bill is separate from Eq.-11 server energy
    assert!(des.energy_spent_j > 0.0);
    // deterministic: same seed, same storm
    let mut sink2 = NullSink;
    let again = exp.run_into(&mut sink2).unwrap().des.unwrap();
    assert_eq!(des.retries, again.retries);
    assert_eq!(des.retry_energy_j.to_bits(), again.retry_energy_j.to_bits());
}

#[test]
fn retry_exhaustion_drops_the_cell_not_the_run() {
    // zero retries allowed: the first outage on a transfer kills the
    // cell, but the run must still drain and balance its books
    let spec = FaultsSpec {
        link_outage_rate_hz: 10.0,
        max_retries: 0,
        ..Default::default()
    };
    let exp = faulty(spec, Policy::Sync, 4, 6, 3, 7);
    let mut sink = CollectSink::default();
    let outcome = exp.run_into(&mut sink).unwrap();
    let des = outcome.des.unwrap();
    assert!(des.dropped > 0, "rate 10 Hz with 0 retries must drop cells");
    assert_eq!(des.launched, outcome.cells as u64 + des.dropped);
    assert_eq!(des.retries, 0, "no retransmissions were allowed");
    assert!(des.makespan_s.is_finite() && des.makespan_s > 0.0);
}

#[test]
fn sync_timeout_factor_demotes_stragglers() {
    // a vanishing outage rate arms the plane without ever striking;
    // the tight timeout then demotes whoever outlives the deadline
    let spec = FaultsSpec {
        link_outage_rate_hz: 1e-12,
        timeout_factor: 0.25,
        ..Default::default()
    };
    let exp = faulty(spec.clone(), Policy::Sync, 1, 8, 2, 7);
    let mut sink = NullSink;
    let des = exp.run_into(&mut sink).unwrap().des.unwrap();
    assert!(
        des.timeout_demotions > 0,
        "capacity 1 with a 0.25x deadline must demote someone"
    );
    assert_eq!(des.dropped, des.timeout_demotions);
    // without the timeout, the same storm-free run drops nothing
    let lax = FaultsSpec {
        timeout_factor: 0.0,
        ..spec
    };
    let exp = faulty(lax, Policy::Sync, 1, 8, 2, 7);
    let mut sink = NullSink;
    let des = exp.run_into(&mut sink).unwrap().des.unwrap();
    assert_eq!(des.timeout_demotions, 0);
    assert_eq!(des.dropped, 0);
}

fn storm_spec() -> FaultsSpec {
    FaultsSpec {
        link_outage_rate_hz: 0.3,
        slot_fail_prob: 0.2,
        burst_rate_per_round: 1.0,
        ..Default::default()
    }
}

fn assert_runs_match(
    a: (&CollectSink, &edgesplit::exp::RunOutcome),
    b: (&CollectSink, &edgesplit::exp::RunOutcome),
) {
    let (sink_a, out_a) = a;
    let (sink_b, out_b) = b;
    assert_eq!(out_a.cells, out_b.cells);
    assert_eq!(sink_a.records.len(), sink_b.records.len());
    for (x, y) in sink_a.records.iter().zip(&sink_b.records) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.device_idx, y.device_idx);
        assert_eq!(x.cut, y.cut);
        assert_eq!(x.cost.to_bits(), y.cost.to_bits());
        assert_eq!(x.delay_s.to_bits(), y.delay_s.to_bits());
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
    }
    let (da, db) = (out_a.des.as_ref().unwrap(), out_b.des.as_ref().unwrap());
    assert_eq!(da.makespan_s.to_bits(), db.makespan_s.to_bits());
    assert_eq!(da.energy_spent_j.to_bits(), db.energy_spent_j.to_bits());
    assert_eq!(da.retry_energy_j.to_bits(), db.retry_energy_j.to_bits());
    assert_eq!(da.retries, db.retries);
    assert_eq!(da.timeout_demotions, db.timeout_demotions);
    assert_eq!(da.failovers, db.failovers);
    assert_eq!(da.slot_failures, db.slot_failures);
    assert_eq!(da.slot_repairs, db.slot_repairs);
    assert_eq!(da.dropped, db.dropped);
    assert_eq!(da.launched, db.launched);
    assert_eq!(da.server.served_jobs, db.server.served_jobs);
    assert_eq!(da.server.busy_slot_s.to_bits(), db.server.busy_slot_s.to_bits());
}

#[test]
fn checkpoint_resume_mid_storm_is_bit_identical_through_the_api() {
    // all three injection planes armed; freeze mid-run, round-trip the
    // envelope through a file, resume, and require the full record
    // stream and every counter bit for bit
    let exp = faulty(storm_spec(), Policy::Sync, 2, 6, 3, 11);
    let mut full_sink = CollectSink::default();
    let full = exp.run_into(&mut full_sink).unwrap();

    let snap = match exp.checkpoint_at(0.5).unwrap() {
        RunState::Checkpoint(snap) => snap,
        RunState::Done(_) => panic!("a 3-round storm run cannot drain by t = 0.5 s"),
    };
    // the engine freezes on the last event at or before the instant,
    // with the first strictly-later event still pending
    assert!(snap.now_s <= 0.5, "clock ran past the checkpoint instant");
    assert!(snap.events.iter().any(|(t, _, _)| *t > 0.5));

    let dir = std::env::temp_dir().join("edgesplit-des-faults-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("storm.ckpt");
    let path = path.to_str().unwrap();
    checkpoint::write_to(path, &snap).unwrap();
    let loaded = checkpoint::read_from(path).unwrap();
    let _ = std::fs::remove_file(path);

    let mut resumed_sink = CollectSink::default();
    let resumed = exp.resume_into(&loaded, &mut resumed_sink).unwrap();
    assert_runs_match((&full_sink, &full), (&resumed_sink, &resumed));
}

#[test]
fn checkpoint_after_the_horizon_reports_done() {
    let exp = faulty(storm_spec(), Policy::Async, 2, 4, 2, 3);
    match exp.checkpoint_at(1e9).unwrap() {
        RunState::Done(out) => {
            assert!(out.makespan_s < 1e9);
            assert!(!out.records.is_empty());
        }
        RunState::Checkpoint(_) => panic!("nothing can still be pending at t = 1e9 s"),
    }
    // the round engine has no virtual clock to pause
    let round = ExperimentBuilder::preset("dense-urban")
        .devices(4)
        .rounds(1)
        .build()
        .unwrap();
    let err = round.checkpoint_at(1.0).unwrap_err();
    assert!(err.to_string().contains("event engine"), "{err}");
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_experiment() {
    let exp = faulty(storm_spec(), Policy::Sync, 2, 6, 3, 11);
    let snap = match exp.checkpoint_at(0.5).unwrap() {
        RunState::Checkpoint(snap) => snap,
        RunState::Done(_) => panic!("run drained early"),
    };
    // same preset, different seed → different fingerprint
    let other = faulty(storm_spec(), Policy::Sync, 2, 6, 3, 12);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sink = NullSink;
        let _ = other.resume_into(&snap, &mut sink);
    }));
    assert!(result.is_err(), "foreign checkpoint must be refused");
}

#[test]
fn single_cell_bursts_degrade_to_the_device_heavy_cut() {
    // with one cell there is no runner-up site: a struck launch must
    // fall back to the degraded device-heavy cut instead of dying
    let spec = FaultsSpec {
        burst_rate_per_round: 1.0,
        ..Default::default()
    };
    let exp = faulty(spec, Policy::Sync, 4, 6, 3, 7);
    let mut sink = CollectSink::default();
    let outcome = exp.run_into(&mut sink).unwrap();
    let des = outcome.des.unwrap();
    assert!(des.failovers > 0, "a per-round burst must strike someone");
    assert_eq!(des.dropped, 0, "degradation must not cost any cell");
    assert_eq!(outcome.cells, 18);
}
