//! Acceptance tests for the discrete-event fleet engine (ISSUE 2):
//!
//! * `sync` reproduces the synchronous round engine's records — and
//!   therefore its per-round delay/energy totals — **bit-identically**
//!   on the dense-urban preset;
//! * `semi-sync` and `async` are deterministic across thread counts;
//! * both show higher server utilization than the `sync` baseline on
//!   the heterogeneous-fleet preset (the contended-server payoff).

use std::sync::Arc;

use edgesplit::config::scenario::{Scenario, DENSE_URBAN, HETEROGENEOUS_FLEET};
use edgesplit::coordinator::{RoundRecord, Scheduler, Strategy};
use edgesplit::des::{sweep, DesConfig, DesEngine, DesOutcome, Policy};
use edgesplit::exp::verify::verify_bit_identical;
use edgesplit::util::benchkit::Bencher;

fn run_des(sc: Scenario, n: usize, rounds: usize, seed: u64, des: DesConfig) -> DesOutcome {
    let mut cfg = sc.config(n, seed).unwrap();
    cfg.workload.rounds = rounds;
    let sched = Arc::new(Scheduler::new(cfg, sc.state, Strategy::Card));
    DesEngine::new(sched, des).run()
}

#[test]
fn sync_des_bit_identical_to_round_engine_on_dense_urban() {
    let mut cfg = DENSE_URBAN.config(12, 7).unwrap();
    cfg.workload.rounds = 3;
    let sched = Arc::new(Scheduler::new(cfg, DENSE_URBAN.state, Strategy::Card));
    let reference = sched.run_parallel(4);

    let out = DesEngine::new(
        sched.clone(),
        DesConfig {
            policy: Policy::Sync,
            capacity: 4,
            batch: 1,
        },
    )
    .run();
    let des_records: Vec<RoundRecord> = out.records.iter().map(|r| r.record.clone()).collect();
    if let Err(e) = verify_bit_identical(&reference, &des_records) {
        panic!("sync DES diverged from the round engine: {e:#}");
    }

    // per-round delay and energy totals, summed in the engine's record
    // order, must carry identical bits
    for round in 0..3 {
        let total = |records: &[RoundRecord]| -> (f64, f64) {
            records
                .iter()
                .filter(|r| r.round == round)
                .fold((0.0, 0.0), |(d, e), r| (d + r.delay_s, e + r.energy_j))
        };
        let (d_ref, e_ref) = total(&reference);
        let (d_des, e_des) = total(&des_records);
        assert_eq!(d_ref.to_bits(), d_des.to_bits(), "round {round} delay total");
        assert_eq!(e_ref.to_bits(), e_des.to_bits(), "round {round} energy total");
    }
}

#[test]
fn sync_bit_compat_holds_under_server_contention() {
    // queueing delays the timeline but must never perturb a record
    let mut cfg = DENSE_URBAN.config(9, 21).unwrap();
    cfg.workload.rounds = 2;
    let sched = Arc::new(Scheduler::new(cfg, DENSE_URBAN.state, Strategy::Card));
    let reference = sched.run_parallel(2);
    for (capacity, batch) in [(1, 1), (2, 3), (64, 1)] {
        let out = DesEngine::new(
            sched.clone(),
            DesConfig {
                policy: Policy::Sync,
                capacity,
                batch,
            },
        )
        .run();
        let recs: Vec<RoundRecord> = out.records.iter().map(|r| r.record.clone()).collect();
        if let Err(e) = verify_bit_identical(&reference, &recs) {
            panic!("capacity {capacity} batch {batch}: {e:#}");
        }
    }
}

#[test]
fn semi_sync_and_async_deterministic_across_thread_counts() {
    // the engine itself is serial; the sweep fans points out across
    // workers — reported metrics must not depend on the fan-out
    let policies = [
        Policy::SemiSync {
            deadline_factor: 1.2,
        },
        Policy::Async,
    ];
    let run = |threads: usize| {
        let mut bench = Bencher::new("des-det");
        sweep(
            &[HETEROGENEOUS_FLEET],
            &[10],
            &policies,
            Some(2),
            2,
            1,
            threads,
            5,
            &mut bench,
        )
        .unwrap()
    };
    let a = run(1);
    let b = run(6);
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.policy, y.policy);
        assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits(), "{}", x.policy);
        assert_eq!(x.completed, y.completed, "{}", x.policy);
        assert_eq!(x.dropped, y.dropped, "{}", x.policy);
        assert_eq!(x.departures, y.departures, "{}", x.policy);
        assert_eq!(
            x.server_utilization.to_bits(),
            y.server_utilization.to_bits(),
            "{}",
            x.policy
        );
        assert_eq!(
            x.round_latency.p95.to_bits(),
            y.round_latency.p95.to_bits(),
            "{}",
            x.policy
        );
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{}", x.policy);
    }
}

#[test]
fn semi_sync_and_async_beat_sync_utilization_on_heterogeneous_fleet() {
    let des = |policy| DesConfig {
        policy,
        capacity: 2,
        batch: 1,
    };
    let sync = run_des(HETEROGENEOUS_FLEET, 12, 3, 7, des(Policy::Sync));
    let semi = run_des(
        HETEROGENEOUS_FLEET,
        12,
        3,
        7,
        des(Policy::SemiSync {
            deadline_factor: 1.1,
        }),
    );
    let async_ = run_des(HETEROGENEOUS_FLEET, 12, 3, 7, des(Policy::Async));

    assert!(
        async_.server.utilization > sync.server.utilization,
        "async {} !> sync {}",
        async_.server.utilization,
        sync.server.utilization
    );
    assert!(
        semi.server.utilization > sync.server.utilization,
        "semi-sync {} !> sync {}",
        semi.server.utilization,
        sync.server.utilization
    );
    // the mechanisms behind the numbers: semi-sync sheds stragglers,
    // async keeps the queue fed without a barrier
    assert!(semi.dropped > 0, "1.1× deadline shed no stragglers");
    assert!(async_.peak_staleness > 0, "async observed no staleness");
}

#[test]
fn heterogeneous_preset_churn_drives_departures_deterministically() {
    // the preset ships Poisson churn; cell accounting must stay exact
    // and repeated runs identical
    let cfg = HETEROGENEOUS_FLEET.config(8, 3).unwrap();
    assert!(cfg.churn.enabled(), "preset should carry a [churn] table");
    let out = run_des(
        HETEROGENEOUS_FLEET,
        8,
        4,
        3,
        DesConfig {
            policy: Policy::Async,
            capacity: 2,
            batch: 1,
        },
    );
    assert_eq!(out.launched, out.records.len() as u64 + out.dropped);
    assert!(out.departures >= out.arrivals);
    assert!(out.aggregator.is_consistent());
    let again = run_des(
        HETEROGENEOUS_FLEET,
        8,
        4,
        3,
        DesConfig {
            policy: Policy::Async,
            capacity: 2,
            batch: 1,
        },
    );
    assert_eq!(out.makespan_s.to_bits(), again.makespan_s.to_bits());
    assert_eq!(out.departures, again.departures);
    assert_eq!(out.records.len(), again.records.len());
}

#[test]
fn des_sweep_json_reports_the_utilization_ordering() {
    // the BENCH_des.json payload itself must witness the acceptance
    // criterion on the heterogeneous-fleet preset
    let mut bench = Bencher::new("des-accept");
    let policies = [
        Policy::Sync,
        Policy::SemiSync {
            deadline_factor: 1.1,
        },
        Policy::Async,
    ];
    let s = sweep(
        &[HETEROGENEOUS_FLEET],
        &[12],
        &policies,
        Some(3),
        2,
        1,
        4,
        7,
        &mut bench,
    )
    .unwrap();
    let util = |name: &str| {
        s.points
            .iter()
            .find(|p| p.policy == name)
            .map(|p| p.server_utilization)
            .unwrap()
    };
    assert!(util("semi-sync") > util("sync"));
    assert!(util("async") > util("sync"));
    let js = s.to_json().to_string();
    assert!(js.contains("des-sweep/v1"));
    assert!(edgesplit::util::json::Json::parse(&js).is_ok());
}
