//! Property test for the online aggregation path (DESIGN.md §18): the
//! `SummarySink` fold — which consumes the engine's SoA windows
//! column-wise via `Summary::push_batch`, never materializing a
//! `RoundRecord` — must agree with the offline
//! `Summary::from_records` fold over the collected stream on every
//! statistic the sweeps report, bit for bit, across every scenario
//! preset, serial and pooled.

use edgesplit::config::scenario;
use edgesplit::exp::ExperimentBuilder;
use edgesplit::sim::Summary;
use edgesplit::util::stats::Accum;

const DEVICES: usize = 40;
const ROUNDS: usize = 4;
const SEED: u64 = 17;

fn assert_accums_bit_equal(which: &str, a: &Accum, b: &Accum, ctx: &str) {
    assert_eq!(a.count(), b.count(), "{ctx}: {which} count");
    assert_eq!(a.mean().to_bits(), b.mean().to_bits(), "{ctx}: {which} mean");
    assert_eq!(a.var().to_bits(), b.var().to_bits(), "{ctx}: {which} var");
    assert_eq!(a.min().to_bits(), b.min().to_bits(), "{ctx}: {which} min");
    assert_eq!(a.max().to_bits(), b.max().to_bits(), "{ctx}: {which} max");
}

fn assert_summaries_bit_equal(online: &Summary, offline: &Summary, ctx: &str) {
    for (which, a, b) in [
        ("delay", &online.delay, &offline.delay),
        ("energy", &online.energy, &offline.energy),
        ("device_compute", &online.device_compute, &offline.device_compute),
        ("server_compute", &online.server_compute, &offline.server_compute),
        ("transmission", &online.transmission, &offline.transmission),
        ("cost", &online.cost, &offline.cost),
    ] {
        assert_accums_bit_equal(which, a, b, ctx);
    }
    assert_eq!(online.cells(), offline.cells(), "{ctx}: cells");
    assert_eq!(online.cut_counts, offline.cut_counts, "{ctx}: cut histogram");
    assert_eq!(
        online.mean_cut().to_bits(),
        offline.mean_cut().to_bits(),
        "{ctx}: mean cut"
    );
    assert_eq!(
        online.mean_freq_ghz().to_bits(),
        offline.mean_freq_ghz().to_bits(),
        "{ctx}: mean freq"
    );
    // the delay reservoirs saw the same push sequence, so they hold
    // the same samples in the same slots (exact below the cap here)
    let (sa, sb) = (online.delay_samples.as_slice(), offline.delay_samples.as_slice());
    assert_eq!(sa.len(), sb.len(), "{ctx}: reservoir size");
    assert!(online.delay_samples.is_exact(), "{ctx}: test fleet must stay below cap");
    for (i, (x, y)) in sa.iter().zip(sb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: reservoir sample {i}");
    }
}

/// Online (SoA column fold) vs offline (record-stream fold) on every
/// preset, serial and pooled: the satellite-3 parity property.
#[test]
fn summary_sink_online_fold_matches_offline_on_every_preset() {
    for sc in &scenario::ALL {
        for threads in [1, 8] {
            let build = || {
                ExperimentBuilder::preset(sc.name)
                    .devices(DEVICES)
                    .rounds(ROUNDS)
                    .seed(SEED)
                    .threads(threads)
                    .build()
                    .unwrap()
            };
            // online: SummarySink folds SoA windows column-wise
            let (online, outcome) = build().run_summary().unwrap();
            // offline: materialize the stream, fold per record
            let records = build().run_collect().unwrap();
            assert_eq!(outcome.cells, records.len());
            let offline = Summary::from_records(&records);
            let ctx = format!("{} × {threads} thread(s)", sc.name);
            assert_summaries_bit_equal(&online, &offline, &ctx);
        }
    }
}
