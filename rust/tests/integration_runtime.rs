//! Integration: PJRT runtime executing the real AOT artifacts.
//!
//! Requires `artifacts/tiny` (built by `make artifacts`).  Tests
//! self-skip with a loud message when artifacts are missing so plain
//! `cargo test` still passes in a fresh checkout.

use edgesplit::data::{Batcher, Corpus};
use edgesplit::runtime::{artifact_dir, ArtifactStore, HostTensor, SplitExecutor};
use edgesplit::util::rng::Rng;

fn open_tiny() -> Option<ArtifactStore> {
    let dir = artifact_dir("tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: {dir:?} missing — run `make artifacts`");
        return None;
    }
    Some(ArtifactStore::open(dir).expect("opening tiny artifacts"))
}

fn tiny_executor(seed: u64) -> Option<SplitExecutor> {
    let store = open_tiny()?;
    let cfg = store.config.clone();
    let batchers = (0..2)
        .map(|i| {
            let mut rng = Rng::new(100 + i as u64);
            let corpus = Corpus::synthetic(i, 20_000, 0.1, &mut rng);
            Batcher::new(corpus, cfg.batch_size, cfg.seq_len, 200 + i as u64)
        })
        .collect();
    Some(SplitExecutor::new(store, batchers, 0.5, seed).expect("executor"))
}

#[test]
fn manifest_segments_present() {
    let Some(store) = open_tiny() else { return };
    for seg in [
        "embed_fwd",
        "layer_fwd",
        "layer_bwd",
        "head_loss_grad",
        "adapter_sgd",
        "train_step",
    ] {
        assert!(store.segments.contains_key(seg), "missing {seg}");
    }
    assert_eq!(store.config.name, "tiny");
    assert_eq!(store.config.n_layers, 6);
}

#[test]
fn adapter_sgd_numerics() {
    // independently verifiable segment: out = v - lr*g
    let Some(mut store) = open_tiny() else { return };
    let ll = store.config.lora_layer_len;
    let v: Vec<f32> = (0..ll).map(|i| (i % 7) as f32 * 0.25).collect();
    let g: Vec<f32> = (0..ll).map(|i| ((i % 3) as f32) - 1.0).collect();
    let vt = HostTensor::from_f32(&[ll], &v).unwrap();
    let gt = HostTensor::from_f32(&[ll], &g).unwrap();
    let lr = HostTensor::from_f32(&[1], &[0.1]).unwrap();
    let out = store.execute("adapter_sgd", &[&vt, &gt, &lr]).unwrap();
    let got = out[0].as_f32().unwrap();
    for i in 0..ll {
        let want = v[i] - 0.1 * g[i];
        assert!((got[i] - want).abs() < 1e-6, "elem {i}: {} vs {want}", got[i]);
    }
}

#[test]
fn embed_fwd_is_table_lookup() {
    let Some(mut store) = open_tiny() else { return };
    let cfg = store.config.clone();
    let mut executor_seed_rng = Rng::new(0);
    let embed_vals: Vec<f32> = (0..cfg.vocab_size * cfg.d_model)
        .map(|_| executor_seed_rng.gauss() as f32)
        .collect();
    let embed = HostTensor::from_f32(&[cfg.vocab_size, cfg.d_model], &embed_vals).unwrap();
    let toks: Vec<i32> = (0..cfg.batch_size * cfg.seq_len)
        .map(|i| (i % cfg.vocab_size) as i32)
        .collect();
    let tokens = HostTensor::from_i32(&[cfg.batch_size, cfg.seq_len], &toks).unwrap();
    let h = store.execute("embed_fwd", &[&tokens, &embed]).unwrap().remove(0);
    assert_eq!(h.shape, vec![cfg.batch_size, cfg.seq_len, cfg.d_model]);
    let hv = h.as_f32().unwrap();
    // row 0, position 3 should equal embed row 3
    for j in 0..cfg.d_model {
        assert_eq!(hv[3 * cfg.d_model + j], embed_vals[3 * cfg.d_model + j]);
    }
}

#[test]
fn execute_validates_shapes() {
    let Some(mut store) = open_tiny() else { return };
    let bad = HostTensor::from_f32(&[3], &[1.0, 2.0, 3.0]).unwrap();
    let err = store.execute("adapter_sgd", &[&bad, &bad, &bad]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest wants"), "unexpected error: {msg}");
    // arity error
    let err2 = store.execute("adapter_sgd", &[&bad]).unwrap_err();
    assert!(format!("{err2:#}").contains("expected 3 inputs"));
}

#[test]
fn split_training_reduces_loss_and_keeps_protocol_invariants() {
    let Some(mut ex) = tiny_executor(42) else { return };
    let i_layers = ex.n_layers();
    let first = ex.train_step(0, i_layers / 2, 0).expect("step");
    // byte-level vocab: initial loss near ln(256) ≈ 5.55
    assert!(
        (first - (256f64).ln()).abs() < 1.5,
        "initial loss {first} far from ln(256)"
    );
    let mut last = first;
    for step in 1..12 {
        // alternate devices and cuts — protocol must hold for any mix
        let dev = step % 2;
        let cut = (step * 2) % (i_layers + 1);
        last = ex.train_step(dev, cut, step).expect("step");
        assert!(
            ex.aggregator.is_consistent(),
            "adapters inconsistent after step {step}"
        );
    }
    assert!(
        last < first - 0.3,
        "loss did not decrease: first {first}, last {last}"
    );
}

#[test]
fn chained_and_fused_steps_agree() {
    // Same seed => identical init & batches; one chained step at any cut
    // must equal one fused train_step to fp32 tolerance.
    let Some(mut a) = tiny_executor(7) else { return };
    let Some(mut b) = tiny_executor(7) else { return };
    let la = a.train_step(0, 3, 0).unwrap();
    let lb = b.fused_train_step(0).unwrap();
    assert!(
        (la - lb).abs() < 1e-4,
        "chained loss {la} vs fused loss {lb}"
    );
    // adapter states must match too
    for l in 0..a.n_layers() {
        let va = a.state.lora[l].as_f32().unwrap();
        let vb = b.state.lora[l].as_f32().unwrap();
        let max_err = va
            .iter()
            .zip(&vb)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 2e-4, "layer {l} adapter divergence {max_err}");
    }
}

#[test]
fn traffic_ledger_matches_datasize_model() {
    let Some(mut ex) = tiny_executor(3) else { return };
    let cfg = ex.store.config.clone();
    ex.train_step(0, 2, 0).unwrap();
    let t = ex.traffic_log.last().unwrap();
    let expect_smashed = (cfg.batch_size * cfg.seq_len * cfg.d_model * 4
        + cfg.batch_size * cfg.seq_len * 4) as f64;
    assert_eq!(t.smashed_up_bytes, expect_smashed);
    let expect_grad = (cfg.batch_size * cfg.seq_len * cfg.d_model * 4) as f64;
    assert_eq!(t.grad_down_bytes, expect_grad);
    // op split: device ops = embed + c fwd + 2c bwd; server = rest
    assert_eq!(t.device_ops, 1 + 2 + 2 * 2);
    assert_eq!(t.server_ops, (6 - 2) + 1 + 2 * (6 - 2));
}

#[test]
fn device_resident_fast_path_matches_host_path() {
    // Same seed: N fast (device-resident) steps must produce the same
    // losses and adapter state as N host-path steps.
    let Some(mut fast) = tiny_executor(23) else { return };
    let Some(mut host) = tiny_executor(23) else { return };
    for step in 0..4 {
        let lf = fast.train_step_device(0, 2, step).unwrap();
        let lh = host.train_step(0, 2, step).unwrap();
        assert!((lf - lh).abs() < 1e-5, "step {step}: fast {lf} vs host {lh}");
    }
    fast.sync_lora_to_host().unwrap();
    for l in 0..fast.n_layers() {
        let a = fast.state.lora[l].as_f32().unwrap();
        let b = host.state.lora[l].as_f32().unwrap();
        let err = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max);
        assert!(err < 1e-5, "layer {l} adapter divergence {err}");
    }
    // protocol invariants hold on the fast path too
    assert!(fast.aggregator.is_consistent());
    assert_eq!(fast.aggregator.merges(), 4);
}

#[test]
fn mixed_fast_and_host_paths_stay_consistent() {
    let Some(mut a) = tiny_executor(29) else { return };
    let Some(mut b) = tiny_executor(29) else { return };
    // a: fast, host, fast — b: host, host, host
    let l1 = a.train_step_device(0, 1, 0).unwrap();
    let l2 = a.train_step(0, 1, 1).unwrap();
    let l3 = a.train_step_device(0, 1, 2).unwrap();
    let m1 = b.train_step(0, 1, 0).unwrap();
    let m2 = b.train_step(0, 1, 1).unwrap();
    let m3 = b.train_step(0, 1, 2).unwrap();
    for (x, y) in [(l1, m1), (l2, m2), (l3, m3)] {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

#[test]
fn cut_does_not_change_numerics() {
    // Same seed, different cuts: loss sequence must be identical — the
    // split moves WHERE ops run, never WHAT is computed.
    let Some(mut a) = tiny_executor(11) else { return };
    let Some(mut b) = tiny_executor(11) else { return };
    for step in 0..3 {
        let la = a.train_step(0, 0, step).unwrap();
        let lb = b.train_step(0, a.n_layers(), step).unwrap();
        assert!((la - lb).abs() < 1e-6, "step {step}: {la} vs {lb}");
    }
}
