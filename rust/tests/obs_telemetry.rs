//! Zero-perturbation property test for the observability layer
//! (DESIGN.md §16): metric collection and Chrome tracing observe the
//! engines, they never steer them.  Records must stay **bitwise
//! identical** with telemetry/tracing on vs. off, across every
//! scenario preset, both engines, and serial vs. pooled execution.
//!
//! Everything lives in ONE `#[test]`: `obs::set_enabled` and
//! `obs::trace::enable` are process-wide switches, and cargo runs a
//! test binary's `#[test]`s concurrently — splitting this into several
//! tests would race the toggles.  The other integration suites run
//! with the defaults (telemetry on, tracing off) and are unaffected.

use std::sync::Arc;

use edgesplit::config::scenario;
use edgesplit::coordinator::{Scheduler, Strategy};
use edgesplit::des::{DesConfig, DesEngine, Policy};
use edgesplit::exp::{verify, ExperimentBuilder};
use edgesplit::obs::{self, registry, trace};
use edgesplit::util::json::Json;

const DEVICES: usize = 5;
const ROUNDS: usize = 2;
const SEED: u64 = 11;

fn round_records(
    preset: &str,
    threads: usize,
) -> anyhow::Result<Vec<edgesplit::coordinator::RoundRecord>> {
    ExperimentBuilder::preset(preset)
        .devices(DEVICES)
        .rounds(ROUNDS)
        .seed(SEED)
        .threads(threads)
        .build()?
        .run_collect()
}

#[test]
fn telemetry_and_tracing_never_perturb_records() -> anyhow::Result<()> {
    for sc in &scenario::ALL {
        // baseline: every observability switch off
        obs::set_enabled(false);
        registry::set_timers_enabled(false);
        trace::disable();
        let baseline = round_records(sc.name, 1)?;

        // everything on: registry + phase timers + trace buffer
        obs::set_enabled(true);
        trace::enable();
        let serial = round_records(sc.name, 1)?;
        let pooled = round_records(sc.name, 4)?;
        verify::verify_bit_identical(&baseline, &serial)?;
        verify::verify_bit_identical(&baseline, &pooled)?;

        // both DES gates (sync-vs-round-engine and the single-cell
        // anchor) with tracing still live: they Err on any divergence
        let mut cfg = sc.config(DEVICES, SEED)?;
        cfg.workload.rounds = ROUNDS;
        verify::verify_des_sync_matches_round_engine(&cfg, sc.state, 2, 1)?;
        verify::verify_single_cell_bit_identity(&cfg, sc.state, 2, 1)?;

        // the §17 anchor with tracing still live: a dormant [faults]
        // table is bitwise invisible even while being observed
        let des = DesConfig {
            policy: Policy::Sync,
            capacity: 2,
            batch: 1,
        };
        verify::verify_zero_fault_rate_is_noop(&cfg, sc.state, des)?;
    }

    // the armed fault plane is itself zero-perturbation to observe: a
    // storm run with every switch off must match the same storm with
    // the registry + tracer live, bit for bit on every counter
    let mut cfg = scenario::DENSE_URBAN.config(DEVICES, SEED)?;
    cfg.workload.rounds = ROUNDS;
    cfg.faults.link_outage_rate_hz = 5.0;
    cfg.faults.slot_fail_prob = 0.3;
    cfg.faults.burst_rate_per_round = 1.0;
    cfg.faults.timeout_factor = 1.5;
    let des = DesConfig {
        policy: Policy::Sync,
        capacity: 2,
        batch: 1,
    };
    let storm = || {
        DesEngine::new(
            Arc::new(Scheduler::new(
                cfg.clone(),
                scenario::DENSE_URBAN.state,
                Strategy::Card,
            )),
            des,
        )
        .run()
    };
    obs::set_enabled(false);
    trace::disable();
    let dark = storm();
    obs::set_enabled(true);
    trace::enable();
    let lit = storm();
    verify::verify_des_outcome_bit_identical(&dark, &lit)?;
    assert!(lit.retries > 0, "a 5 Hz storm must interrupt some transfer");

    // the traced runs above must have recorded spans: engine wall
    // phases at minimum, DES virtual-time activity from the gates
    assert!(!trace::is_empty(), "traced runs recorded no events");
    let n = trace::len();
    assert!(n > 0);

    // write_to drains the buffer into valid Chrome trace_event JSON
    let path = std::env::temp_dir().join("obs_telemetry_trace.json");
    let path = path.to_str().unwrap().to_string();
    trace::write_to(&path)?;
    assert!(trace::is_empty(), "write_to must drain the buffer");
    let parsed = Json::parse(&std::fs::read_to_string(&path)?).expect("trace must be valid JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert_eq!(events.len(), n);
    for ev in events {
        for key in ["name", "ph", "ts", "pid", "tid"] {
            assert!(ev.get(key).is_some(), "event missing {key}");
        }
        if ev.get("ph").and_then(Json::as_str) == Some("X") {
            let dur = ev.get("dur").and_then(Json::as_f64).expect("X needs dur");
            assert!(dur >= 0.0, "negative span duration");
        }
    }
    let _ = std::fs::remove_file(&path);

    // the registry saw the traffic the runs generated
    let snap = obs::Snapshot::collect().to_json();
    assert_eq!(
        snap.get("schema").and_then(Json::as_str),
        Some("edgesplit/telemetry/v1")
    );
    let counters = snap.get("counters").and_then(Json::as_obj).unwrap();
    assert!(
        counters.keys().any(|k| k.starts_with("decision_cache.")),
        "scheduler cache counters missing from snapshot"
    );
    // the observed Cached runs above streamed SoA windows chunk by
    // chunk (DESIGN.md §18) — the chunk counter must have seen them
    let soa_chunks = counters
        .get("round.soa.chunks")
        .and_then(Json::as_f64)
        .expect("round.soa.chunks missing from snapshot");
    assert!(soa_chunks > 0.0, "no SoA chunk fills were counted");
    // the storm run above was observed: its fault counters landed
    for key in [
        "des.faults.retries",
        "des.faults.timeouts",
        "des.faults.failovers",
        "des.faults.slot_failures",
        "des.faults.slot_repairs",
    ] {
        assert!(counters.contains_key(key), "{key} missing from snapshot");
    }
    let retries = counters
        .get("des.faults.retries")
        .and_then(Json::as_f64)
        .unwrap();
    assert!(retries >= lit.retries as f64, "observed storm recorded no retries");
    let hists = snap.get("histograms").and_then(Json::as_obj).unwrap();
    let backoff = hists.get("des.faults.backoff_s").expect("backoff histogram");
    assert!(
        backoff.get("count").and_then(Json::as_f64).unwrap() > 0.0,
        "retries must observe their backoff waits"
    );

    // the per-chunk SoA fill timer is gated on set_timers_enabled
    // (zero-perturbation default): off, the histogram stays silent;
    // on, one run records a sample per chunk filled
    let fill_count = |snap: &Json| {
        snap.at(&["histograms", "round.soa.fill_s", "count"])
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    registry::set_timers_enabled(false);
    let before = fill_count(&obs::Snapshot::collect().to_json());
    round_records(scenario::DENSE_URBAN.name, 2)?;
    let dark_fill = fill_count(&obs::Snapshot::collect().to_json());
    assert_eq!(before, dark_fill, "fill timer recorded while timers were off");
    registry::set_timers_enabled(true);
    round_records(scenario::DENSE_URBAN.name, 2)?;
    let lit_fill = fill_count(&obs::Snapshot::collect().to_json());
    assert!(
        lit_fill > dark_fill,
        "enabled fill timer recorded nothing ({dark_fill} -> {lit_fill})"
    );

    // leave the process-wide defaults behind for any later suite
    trace::disable();
    trace::clear();
    registry::set_timers_enabled(false);
    obs::set_enabled(true);
    Ok(())
}
