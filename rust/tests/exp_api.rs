//! Acceptance tests for the unified experiment API (DESIGN.md §14):
//! builder validation, engine/sink behavior, DES-sync parity through
//! the trait, the multi-cell tier (§15), and the shared report
//! envelope.

use edgesplit::config::{scenario, CellLayout, CellsSpec};
use edgesplit::coordinator::Strategy;
use edgesplit::des::{DesConfig, Policy};
use edgesplit::exp::{
    verify, BuildError, CollectSink, DesSink, ExecMode, ExperimentBuilder, NullSink,
};
use edgesplit::sim::Summary;

// ---------------------------------------------------------------------------
// builder validation
// ---------------------------------------------------------------------------

#[test]
fn rejects_unknown_preset_with_known_names() {
    let err = ExperimentBuilder::preset("nope").devices(4).build().unwrap_err();
    assert!(matches!(&err, BuildError::UnknownPreset(name) if name == "nope"));
    // the message lists the registry so the fix is one copy-paste away
    let msg = err.to_string();
    assert!(msg.contains("dense-urban") && msg.contains("mobile-vehicular"), "{msg}");
}

#[test]
fn rejects_zero_rounds_and_zero_devices() {
    assert!(matches!(
        ExperimentBuilder::preset("dense-urban").devices(4).rounds(0).build(),
        Err(BuildError::ZeroRounds)
    ));
    assert!(matches!(
        ExperimentBuilder::preset("dense-urban").devices(0).build(),
        Err(BuildError::ZeroDevices)
    ));
    assert!(matches!(
        ExperimentBuilder::paper().rounds(0).build(),
        Err(BuildError::ZeroRounds)
    ));
}

#[test]
fn preset_requires_fleet_size_and_config_rejects_one() {
    assert!(matches!(
        ExperimentBuilder::preset("dense-urban").build(),
        Err(BuildError::MissingFleetSize(_))
    ));
    assert!(matches!(
        ExperimentBuilder::paper().devices(8).build(),
        Err(BuildError::FleetSizeWithoutPreset)
    ));
}

#[test]
fn rejects_conflicting_engine_mode_combos() {
    // the Uncached/Ref oracles exist only on the round engine
    let des = DesConfig {
        policy: Policy::Sync,
        capacity: 2,
        batch: 1,
    };
    for mode in [ExecMode::Uncached, ExecMode::Ref] {
        let err = ExperimentBuilder::preset("dense-urban")
            .devices(4)
            .des(des)
            .mode(mode)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, BuildError::OracleOnEventEngine(_)),
            "{mode:?}: {err}"
        );
    }
    // cached mode + DES builds fine
    assert!(ExperimentBuilder::preset("dense-urban").devices(4).des(des).build().is_ok());
}

#[test]
fn rejects_degenerate_des_knobs() {
    let build = |capacity, batch, policy| {
        ExperimentBuilder::preset("dense-urban")
            .devices(4)
            .des(DesConfig {
                policy,
                capacity,
                batch,
            })
            .build()
    };
    let semi = |deadline_factor: f64| Policy::SemiSync { deadline_factor };
    assert!(matches!(build(0, 1, Policy::Sync), Err(BuildError::InvalidDes(_))));
    assert!(matches!(build(1, 0, Policy::Sync), Err(BuildError::InvalidDes(_))));
    assert!(matches!(build(1, 1, semi(0.0)), Err(BuildError::InvalidDes(_))));
    assert!(matches!(build(1, 1, semi(f64::NAN)), Err(BuildError::InvalidDes(_))));
}

#[test]
fn deadline_factor_error_names_the_valid_range_and_value() {
    // a 0 / negative / NaN deadline factor must fail with a message
    // that states the valid range and echoes the rejected value, not
    // just a generic "invalid" — the flag is user-facing
    for bad in [0.0, -1.5, f64::NAN, f64::INFINITY] {
        let err = ExperimentBuilder::preset("dense-urban")
            .devices(4)
            .des(DesConfig {
                policy: Policy::SemiSync {
                    deadline_factor: bad,
                },
                capacity: 1,
                batch: 1,
            })
            .build()
            .unwrap_err();
        assert!(matches!(err, BuildError::InvalidDes(_)), "{bad}: {err}");
        let msg = err.to_string();
        assert!(msg.contains("(0, +inf)"), "{bad}: {msg}");
        assert!(msg.contains(&format!("{bad}")), "{bad}: {msg}");
    }
}

// ---------------------------------------------------------------------------
// the fault plane (DESIGN.md §17)
// ---------------------------------------------------------------------------

#[test]
fn zero_rate_faults_are_bitwise_invisible_on_every_preset() {
    // the zero-perturbation anchor: a [faults] table whose injection
    // rates are all zero — recovery knobs set or not — must leave
    // every record, queue statistic, and counter bitwise identical to
    // a run with the plane entirely absent
    for sc in scenario::ALL {
        let mut cfg = sc.config(8, 3).unwrap();
        cfg.workload.rounds = 2;
        // non-default recovery knobs: the gate zeroes only the rates
        cfg.faults.max_retries = 7;
        cfg.faults.backoff_base_s = 0.1;
        cfg.faults.timeout_factor = 2.0;
        for policy in [
            Policy::Sync,
            Policy::SemiSync {
                deadline_factor: 1.5,
            },
            Policy::Async,
        ] {
            let des = DesConfig {
                policy,
                capacity: 2,
                batch: 1,
            };
            if let Err(e) = verify::verify_zero_fault_rate_is_noop(&cfg, sc.state, des) {
                panic!("{} / {:?}: {e:#}", sc.name, policy);
            }
        }
    }
}

#[test]
fn checkpoint_resume_gate_passes_on_every_preset() {
    // freeze each preset mid-run (with all three injection planes
    // armed), round-trip the envelope, resume, and require the full
    // outcome bit for bit
    for sc in scenario::ALL {
        let mut cfg = sc.config(6, 9).unwrap();
        cfg.workload.rounds = 2;
        cfg.faults.link_outage_rate_hz = 0.3;
        cfg.faults.slot_fail_prob = 0.2;
        cfg.faults.burst_rate_per_round = 0.5;
        let des = DesConfig {
            policy: Policy::Sync,
            capacity: 2,
            batch: 1,
        };
        for t_s in [0.05, 1.0, 1e6] {
            if let Err(e) = verify::verify_checkpoint_resume_bit_identity(&cfg, sc.state, des, t_s)
            {
                panic!("{} @ t={t_s}: {e:#}", sc.name);
            }
        }
    }
}

#[test]
fn bad_config_surfaces_as_typed_config_error() {
    let mut cfg = edgesplit::config::ExpConfig::paper();
    cfg.card.w = 3.0; // out of [0, 1]
    assert!(matches!(
        ExperimentBuilder::from_config(cfg).build(),
        Err(BuildError::Config(_))
    ));
}

// ---------------------------------------------------------------------------
// engine + sink behavior
// ---------------------------------------------------------------------------

#[test]
fn summary_sink_matches_offline_aggregation() {
    let build = || {
        ExperimentBuilder::preset("heterogeneous-fleet")
            .devices(9)
            .rounds(3)
            .seed(11)
            .build()
            .unwrap()
    };
    let records = build().run_collect().unwrap();
    let (online, outcome) = build().run_summary().unwrap();
    let offline = Summary::from_records(&records);
    assert_eq!(outcome.cells, records.len());
    assert_eq!(online.delay.mean().to_bits(), offline.delay.mean().to_bits());
    assert_eq!(online.energy.mean().to_bits(), offline.energy.mean().to_bits());
    assert_eq!(online.cells(), offline.cells());
    assert_eq!(online.cut_counts, offline.cut_counts);
    assert_eq!(online.mean_freq_ghz().to_bits(), offline.mean_freq_ghz().to_bits());
    assert_eq!(
        online.delay_percentiles().p95.to_bits(),
        offline.delay_percentiles().p95.to_bits()
    );
}

#[test]
fn round_engine_reports_preset_and_scheduler_views() {
    let exp = ExperimentBuilder::preset("dense-urban")
        .devices(6)
        .rounds(2)
        .strategy(Strategy::Card)
        .build()
        .unwrap();
    assert_eq!(exp.preset(), Some("dense-urban"));
    assert!(!exp.is_event_engine());
    assert_eq!(exp.mode(), ExecMode::Cached);
    let mut sink = NullSink;
    let outcome = exp.run_into(&mut sink).unwrap();
    assert_eq!(outcome.cells, 12);
    assert!(outcome.des.is_none());
    // the scheduler view exposes cache stats after the run
    let (hits, misses) = exp.scheduler().cache_stats();
    assert!(hits + misses > 0);
}

#[test]
fn event_engine_streams_des_observables() {
    let exp = ExperimentBuilder::preset("dense-urban")
        .devices(6)
        .rounds(2)
        .seed(5)
        .des(DesConfig {
            policy: Policy::Async,
            capacity: 2,
            batch: 1,
        })
        .build()
        .unwrap();
    assert!(exp.is_event_engine());
    let mut sink = DesSink::default();
    let outcome = exp.run_into(&mut sink).unwrap();
    let des = outcome.des.expect("event engine must report DES stats");
    assert_eq!(outcome.cells, 12);
    assert_eq!(sink.latencies.len(), 12);
    assert!(sink.latencies.is_exact());
    assert!(sink.latencies.as_slice().iter().all(|l| *l > 0.0 && l.is_finite()));
    assert!(sink.energy_merged_j > 0.0);
    assert!(des.makespan_s > 0.0);
    assert!(des.server.utilization > 0.0);
    assert!(des.aggregator_consistent);
    // a plain sink sees the embedded analytic records via the default
    // on_des_record forwarding
    let mut collect = CollectSink::default();
    exp.run_into(&mut collect).unwrap();
    assert_eq!(collect.records.len(), 12);
}

#[test]
fn run_trained_refuses_event_engine_and_oracle_modes() {
    use edgesplit::coordinator::{BackendStats, TrainBackend};
    struct Fake;
    impl TrainBackend for Fake {
        fn train_round(&mut self, _: usize, _: usize, _: usize) -> anyhow::Result<BackendStats> {
            Ok(BackendStats {
                mean_loss: 0.0,
                wallclock_s: 0.0,
            })
        }
    }
    let des_exp = ExperimentBuilder::preset("dense-urban")
        .devices(3)
        .rounds(1)
        .des(DesConfig {
            policy: Policy::Sync,
            capacity: 1,
            batch: 1,
        })
        .build()
        .unwrap();
    assert!(des_exp.run_trained(&mut Fake).is_err());
    let oracle_exp = ExperimentBuilder::preset("dense-urban")
        .devices(3)
        .rounds(1)
        .mode(ExecMode::Ref)
        .build()
        .unwrap();
    assert!(oracle_exp.run_trained(&mut Fake).is_err());
    let ok_exp = ExperimentBuilder::preset("dense-urban")
        .devices(3)
        .rounds(1)
        .build()
        .unwrap();
    let recs = ok_exp.run_trained(&mut Fake).unwrap();
    assert_eq!(recs.len(), 3);
    assert!(recs.iter().all(|r| r.loss == Some(0.0)));
}

// ---------------------------------------------------------------------------
// shared determinism gates
// ---------------------------------------------------------------------------

#[test]
fn round_determinism_gate_passes_on_every_preset() {
    for sc in scenario::ALL {
        let exp = ExperimentBuilder::preset(sc.name)
            .devices(8)
            .rounds(2)
            .seed(3)
            .threads(4)
            .build()
            .unwrap();
        if let Err(e) = verify::verify_round_determinism(&exp) {
            panic!("{}: {e:#}", sc.name);
        }
    }
}

#[test]
fn des_sync_gate_passes_even_on_churny_presets() {
    // heterogeneous-fleet ships a [churn] table; the gate runs the
    // churn-free contract on a copy
    let mut cfg = scenario::HETEROGENEOUS_FLEET.config(8, 7).unwrap();
    cfg.workload.rounds = 2;
    verify::verify_des_sync_matches_round_engine(
        &cfg,
        scenario::HETEROGENEOUS_FLEET.state,
        2,
        1,
    )
    .unwrap();
}

// ---------------------------------------------------------------------------
// the multi-cell tier (DESIGN.md §15)
// ---------------------------------------------------------------------------

#[test]
fn rejects_multi_cell_on_the_round_engine() {
    let err = ExperimentBuilder::preset("dense-urban")
        .devices(4)
        .cells(3)
        .build()
        .unwrap_err();
    assert!(matches!(err, BuildError::CellsOnRoundEngine(3)), "{err}");
    assert!(err.to_string().contains("event engine"), "{err}");
    // a single cell is the round engine's own topology — allowed
    assert!(ExperimentBuilder::preset("dense-urban").devices(4).cells(1).build().is_ok());
}

#[test]
fn single_cell_bit_identity_holds_on_every_preset() {
    // the cell-tier anchor, property-tested across the full registry:
    // forcing [cells] back to one cell, the sync DES timeline must
    // reproduce the serial round engine bit for bit — even on presets
    // whose TOML carries its own [cells] table (mobile-vehicular)
    for sc in scenario::ALL {
        let mut cfg = sc.config(8, 3).unwrap();
        cfg.workload.rounds = 2;
        if let Err(e) = verify::verify_single_cell_bit_identity(&cfg, sc.state, 2, 1) {
            panic!("{}: {e:#}", sc.name);
        }
    }
}

#[test]
fn single_cell_per_cell_energy_is_the_global_total() {
    let run = |cells: usize| {
        let exp = ExperimentBuilder::preset("dense-urban")
            .devices(6)
            .rounds(2)
            .seed(5)
            .cells(cells)
            .des(DesConfig {
                policy: Policy::Sync,
                capacity: 2,
                batch: 1,
            })
            .build()
            .unwrap();
        let mut sink = NullSink;
        exp.run_into(&mut sink).unwrap().des.unwrap()
    };
    let single = run(1);
    assert_eq!(single.per_cell.len(), 1);
    assert_eq!(single.handovers, 0);
    assert_eq!(
        single.per_cell[0].energy_spent_j.to_bits(),
        single.energy_spent_j.to_bits()
    );
    // splitting the same fleet across cells conserves the total: every
    // job is dispatched exactly once, on exactly one queue
    let multi = run(3);
    assert_eq!(multi.per_cell.len(), 3);
    let sum: f64 = multi.per_cell.iter().map(|c| c.energy_spent_j).sum();
    assert_eq!(sum.to_bits(), multi.energy_spent_j.to_bits());
    let served: u64 = multi.per_cell.iter().map(|c| c.server.served_jobs).sum();
    assert_eq!(served, multi.server.served_jobs);
}

#[test]
fn mobile_vehicular_fleet_hands_over_across_line_cells() {
    // the acceptance scenario: waypoint vehicles shuttling 60 m at
    // 12 m/s across 4 line cells 60 m apart must re-associate at least
    // once over 8 rounds with the default 3 dB hysteresis
    let exp = ExperimentBuilder::preset("mobile-vehicular")
        .devices(24)
        .seed(7)
        .cells_spec(CellsSpec {
            count: 4,
            layout: CellLayout::Line,
            spacing_m: 60.0,
            hysteresis_db: 3.0,
        })
        .des(DesConfig {
            policy: Policy::Sync,
            capacity: 4,
            batch: 1,
        })
        .build()
        .unwrap();
    let mut sink = NullSink;
    let des = exp.run_into(&mut sink).unwrap().des.unwrap();
    assert!(des.handovers >= 1, "expected at least one handover, got 0");
    let inbound: u64 = des.per_cell.iter().map(|c| c.handovers_in).sum();
    assert_eq!(inbound, des.handovers);
    let sum: f64 = des.per_cell.iter().map(|c| c.energy_spent_j).sum();
    assert_eq!(sum.to_bits(), des.energy_spent_j.to_bits());
}
