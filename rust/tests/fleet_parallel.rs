//! Fleet-scale determinism: the parallel round engine must reproduce
//! the serial reference path **bit for bit** — for any scenario, seed,
//! fleet size, thread count, and strategy.  This is the invariant that
//! makes "as fast as the hardware allows" safe: adding workers can
//! never change a single figure.

use edgesplit::config::scenario::{Scenario, ALL, DENSE_URBAN};
use edgesplit::coordinator::{RoundRecord, Scheduler, Strategy};
use edgesplit::exp::verify::verify_bit_identical;
use edgesplit::prop_assert;
use edgesplit::util::pool;
use edgesplit::util::proptest::{forall, PropConfig};

/// One comparator for the whole suite — the same gate `fleet-sweep`
/// runs at the CLI, so the test and runtime checks can't drift apart.
fn assert_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    if let Err(e) = verify_bit_identical(a, b) {
        panic!("{e:#}");
    }
}

#[test]
fn prop_parallel_matches_serial_bitwise() {
    forall(
        "parallel fleet round == serial path, bit for bit",
        PropConfig {
            seed: 0x00F1_EE75,
            cases: 24,
        },
        |r| {
            let scenario = ALL[r.below(ALL.len() as u64) as usize].name;
            let n_devices = 2 + r.below(30) as usize;
            let seed = r.next_u64();
            let threads = 1 + r.below(8) as usize;
            let rounds = 1 + r.below(4) as usize;
            let strategy = match r.below(3) {
                0 => Strategy::Card,
                1 => Strategy::RandomCut,
                _ => Strategy::StaticCut(1 + r.below(32) as usize),
            };
            (scenario, n_devices, seed, threads, rounds, strategy)
        },
        |&(name, n_devices, seed, threads, rounds, strategy)| {
            let sc = Scenario::by_name(name).expect("registry name");
            let mut cfg = sc.config(n_devices, seed).map_err(|e| e.to_string())?;
            cfg.workload.rounds = rounds;
            let sched = Scheduler::new(cfg, sc.state, strategy);
            let serial = sched.run_analytic().map_err(|e| format!("{e:#}"))?;
            let parallel = sched.run_parallel(threads);
            prop_assert!(
                verify_bit_identical(&serial, &parallel).is_ok(),
                "parallel != serial for {name} n={n_devices} ({threads} threads)",
            );
            Ok(())
        },
    );
}

#[test]
fn dense_urban_1000_devices_completes_and_matches_serial() {
    // ISSUE acceptance: fleet-sweep's 1 000-device dense-urban point
    // completes, and the parallel metrics match the serial path
    // bit-identically for a fixed seed.
    let mut cfg = DENSE_URBAN.config(1000, 7).unwrap();
    cfg.workload.rounds = 2;
    let sched = Scheduler::new(cfg, DENSE_URBAN.state, Strategy::Card);
    let parallel = sched.run_parallel(pool::default_parallelism());
    assert_eq!(parallel.len(), 2000);
    assert!(parallel.iter().all(|r| r.delay_s > 0.0 && r.delay_s.is_finite()));
    let serial = sched.run_analytic().unwrap();
    assert_bit_identical(&serial, &parallel);
}

#[test]
fn every_scenario_runs_at_fleet_scale() {
    for sc in ALL {
        let mut cfg = sc.config(25, 1).unwrap();
        cfg.workload.rounds = 2;
        let sched = Scheduler::new(cfg, sc.state, Strategy::Card);
        let recs = sched.run_parallel(4);
        assert_eq!(recs.len(), 50, "{}", sc.name);
        for r in &recs {
            assert!(r.delay_s > 0.0 && r.energy_j >= 0.0, "{}", sc.name);
            assert!(r.rate_up_bps > 0.0, "{}", sc.name);
        }
    }
}

#[test]
fn thread_count_never_changes_results() {
    let mut cfg = DENSE_URBAN.config(17, 99).unwrap();
    cfg.workload.rounds = 3;
    let sched = Scheduler::new(cfg, DENSE_URBAN.state, Strategy::Card);
    let reference = sched.run_parallel(1);
    for threads in [2, 3, 8, 32] {
        assert_bit_identical(&reference, &sched.run_parallel(threads));
    }
}

#[test]
fn scenarios_produce_distinct_physics() {
    // same seed, same fleet size: the registry's channel/placement
    // differences must show up in the realized metrics
    let run = |sc: Scenario| {
        let mut cfg = sc.config(10, 5).unwrap();
        cfg.workload.rounds = 2;
        let sched = Scheduler::new(cfg, sc.state, Strategy::Card);
        let recs = sched.run_parallel(4);
        recs.iter().map(|r| r.delay_s).sum::<f64>() / recs.len() as f64
    };
    let urban = run(DENSE_URBAN);
    let bursty = run(Scenario::by_name("bursty-channel").unwrap());
    assert!(
        (urban - bursty).abs() > 1e-9,
        "scenarios should realize different mean delays: {urban} vs {bursty}"
    );
}
