//! Integration: the full stack — the experiment API (CARD decisions)
//! driving the SplitExecutor (real PJRT compute) — plus
//! failure-injection tests on the artifact plumbing.  Requires
//! `artifacts/tiny` (self-skips).

use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::Strategy;
use edgesplit::data::{Batcher, Corpus};
use edgesplit::exp::ExperimentBuilder;
use edgesplit::runtime::{artifact_dir, ArtifactStore, SplitExecutor};
use edgesplit::util::rng::Rng;

fn artifacts_available() -> bool {
    let ok = artifact_dir("tiny").join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts`");
    }
    ok
}

fn executor(seed: u64, n_dev: usize) -> SplitExecutor {
    let store = ArtifactStore::open(artifact_dir("tiny")).unwrap();
    let cfg = store.config.clone();
    let batchers = (0..n_dev)
        .map(|i| {
            let mut rng = Rng::new(seed ^ (50 + i as u64));
            Batcher::new(
                Corpus::synthetic(i, 20_000, 0.1, &mut rng),
                cfg.batch_size,
                cfg.seq_len,
                seed ^ (70 + i as u64),
            )
        })
        .collect();
    SplitExecutor::new(store, batchers, 0.5, seed).unwrap()
}

#[test]
fn scheduler_drives_real_training_with_card() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = ExpConfig::paper();
    cfg.workload.arch = "tiny".into();
    cfg.workload.rounds = 2;
    cfg.workload.local_epochs = 2;
    let mut ex = executor(3, cfg.devices.len());
    let experiment = ExperimentBuilder::from_config(cfg)
        .channel_state(ChannelState::Normal)
        .strategy(Strategy::Card)
        .build()
        .unwrap();
    let recs = experiment.run_trained(&mut ex).unwrap();
    assert_eq!(recs.len(), 10); // 5 devices × 2 rounds
    assert!(recs.iter().all(|r| r.loss.is_some()));
    assert_eq!(ex.loss_log.len(), 20); // ×2 epochs
    // losses finite and in a sane band
    for (_, l) in &ex.loss_log {
        assert!(l.is_finite() && *l > 0.0 && *l < 10.0);
    }
    assert!(ex.aggregator.is_consistent());
}

#[test]
fn every_strategy_trains_identically_in_loss_space() {
    // The split moves computation, not math: per-step losses under any
    // strategy must coincide for the same seed (Stage-protocol check at
    // system level).
    if !artifacts_available() {
        return;
    }
    let run = |strategy| {
        let mut cfg = ExpConfig::paper();
        cfg.workload.arch = "tiny".into();
        cfg.workload.rounds = 1;
        cfg.workload.local_epochs = 2;
        let mut ex = executor(9, cfg.devices.len());
        let experiment = ExperimentBuilder::from_config(cfg)
            .channel_state(ChannelState::Normal)
            .strategy(strategy)
            .build()
            .unwrap();
        experiment.run_trained(&mut ex).unwrap();
        ex.loss_log.iter().map(|x| x.1).collect::<Vec<_>>()
    };
    let a = run(Strategy::Card);
    let b = run(Strategy::DeviceOnly);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-6, "{x} vs {y}");
    }
}

#[test]
fn non_iid_devices_have_different_losses() {
    if !artifacts_available() {
        return;
    }
    let mut ex = executor(21, 2);
    let l0 = ex.train_step(0, 3, 0).unwrap();
    let l1 = ex.train_step(1, 3, 0).unwrap();
    // different corpora → different losses (but same magnitude)
    assert!((l0 - l1).abs() > 1e-6);
    assert!((l0 - l1).abs() < 2.0);
}

#[test]
fn longer_training_monotone_trend() {
    if !artifacts_available() {
        return;
    }
    let mut ex = executor(33, 1);
    let mut losses = Vec::new();
    for step in 0..20 {
        losses.push(ex.train_step(0, 2, step).unwrap());
    }
    let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
    let tail: f64 = losses[15..].iter().sum::<f64>() / 5.0;
    assert!(
        tail < head - 0.2,
        "no learning trend: head {head:.3} tail {tail:.3}"
    );
}

// ---------------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------------

#[test]
fn missing_artifact_dir_fails_loudly() {
    let err = ArtifactStore::open("artifacts/definitely-not-here").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join("edgesplit-corrupt-manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json !").unwrap();
    let err = ArtifactStore::open(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("manifest"));
}

#[test]
fn manifest_referencing_missing_hlo_rejected() {
    let dir = std::env::temp_dir().join("edgesplit-missing-hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"config":{"name":"x","vocab_size":4,"d_model":4,"n_layers":1,
            "n_heads":1,"d_ff":4,"seq_len":4,"batch_size":1,"lora_rank":1,
            "base_layer_len":4,"lora_layer_len":4,"head_len":4},
            "artifacts":{"ghost":{"file":"ghost.hlo.txt","inputs":[],"outputs":[]}},
            "layouts":{}}"#,
    )
    .unwrap();
    let err = ArtifactStore::open(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("ghost"));
}

#[test]
fn garbage_hlo_text_fails_at_compile_not_crash() {
    if !artifacts_available() {
        return;
    }
    let dir = std::env::temp_dir().join("edgesplit-garbage-hlo");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // copy a valid manifest but replace one HLO file with garbage
    let src = artifact_dir("tiny");
    for entry in std::fs::read_dir(&src).unwrap() {
        let p = entry.unwrap().path();
        std::fs::copy(&p, dir.join(p.file_name().unwrap())).unwrap();
    }
    std::fs::write(dir.join("adapter_sgd.hlo.txt"), "HloModule broken\n garbage(").unwrap();
    let mut store = ArtifactStore::open(&dir).unwrap();
    let ll = store.config.lora_layer_len;
    let v = edgesplit::runtime::HostTensor::zeros(&[ll], edgesplit::runtime::DType::F32);
    let lr = edgesplit::runtime::HostTensor::from_f32(&[1], &[0.1]).unwrap();
    let err = store.execute("adapter_sgd", &[&v, &v, &lr]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("adapter_sgd"),
        "error should name the segment: {msg}"
    );
}

#[test]
fn executor_rejects_mismatched_batcher() {
    if !artifacts_available() {
        return;
    }
    let store = ArtifactStore::open(artifact_dir("tiny")).unwrap();
    let mut rng = Rng::new(0);
    let corpus = Corpus::synthetic(0, 10_000, 0.1, &mut rng);
    let bad = Batcher::new(corpus, 2, 16, 0); // wrong shape for tiny
    let err = SplitExecutor::new(store, vec![bad], 0.5, 0).unwrap_err();
    assert!(format!("{err:#}").contains("does not match artifact config"));
}

#[test]
fn executor_rejects_out_of_range_cut_and_device() {
    if !artifacts_available() {
        return;
    }
    let mut ex = executor(5, 1);
    assert!(ex.train_step(0, 99, 0).is_err());
    assert!(ex.train_step(7, 0, 0).is_err());
}
