//! Integration: figure harnesses + config system + CLI, end to end on
//! the analytic path (no artifacts needed).

use edgesplit::cli::{Args, FlagSpec};
use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::Strategy;
use edgesplit::exp::ExperimentBuilder;
use edgesplit::sim::{ablate, fig3, fig4, reduction_pct};

fn quick() -> ExpConfig {
    let mut cfg = ExpConfig::paper();
    cfg.workload.rounds = 8;
    cfg
}

// ---------------------------------------------------------------------------
// Fig. 3
// ---------------------------------------------------------------------------

#[test]
fn fig3_full_reproduction_structure() {
    let cfg = quick();
    for state in ChannelState::ALL {
        let r = fig3::run(&cfg, state).unwrap();
        assert_eq!(r.records.len(), 5 * 8);
        // every decision an endpoint (paper Fig. 3a finding)
        for c in r.cut_matrix().iter().flatten() {
            assert!(*c == 0 || *c == r.n_layers);
        }
    }
}

#[test]
fn fig3_is_deterministic_across_runs() {
    let cfg = quick();
    let a = fig3::run(&cfg, ChannelState::Poor).unwrap();
    let b = fig3::run(&cfg, ChannelState::Poor).unwrap();
    assert_eq!(a.cut_matrix(), b.cut_matrix());
    assert_eq!(a.freq_matrix(), b.freq_matrix());
}

#[test]
fn fig3_seed_changes_realization() {
    let mut c2 = quick();
    c2.seed = 999;
    let a = fig3::run(&quick(), ChannelState::Poor).unwrap();
    let b = fig3::run(&c2, ChannelState::Poor).unwrap();
    assert_ne!(
        a.freq_matrix(),
        b.freq_matrix(),
        "different seeds must realize different channels"
    );
}

// ---------------------------------------------------------------------------
// Fig. 4
// ---------------------------------------------------------------------------

#[test]
fn fig4_reproduces_paper_shape() {
    let r = fig4::run(&quick()).unwrap();
    assert_eq!(r.cells.len(), 9);
    // headline direction: CARD saves large fractions on both axes
    assert!(r.delay_reduction_vs_device_only_pct > 40.0);
    assert!(r.energy_reduction_vs_server_only_pct > 25.0);
    // Poor channel hurts everyone's delay
    let delay = |state: ChannelState, m: &str| {
        r.cells
            .iter()
            .find(|c| c.state == state && c.strategy == m)
            .unwrap()
            .mean_delay_s
    };
    for m in ["CARD (proposed)", "Server-only", "Device-only"] {
        assert!(delay(ChannelState::Poor, m) > delay(ChannelState::Good, m));
    }
}

#[test]
fn fig4_energy_independent_of_channel_for_fixed_strategies() {
    // Server-only and Device-only pick fixed (c, f) regardless of rates,
    // so their server energy must be channel-invariant (Eq. 11 has no
    // rate term).
    let r = fig4::run(&quick()).unwrap();
    for m in ["Server-only", "Device-only"] {
        let es: Vec<f64> = r
            .cells
            .iter()
            .filter(|c| c.strategy == m)
            .map(|c| c.mean_energy_j)
            .collect();
        assert!((es[0] - es[1]).abs() < 1e-6 && (es[1] - es[2]).abs() < 1e-6);
    }
}

// ---------------------------------------------------------------------------
// ablations
// ---------------------------------------------------------------------------

#[test]
fn ablate_w_pareto_frontier() {
    let pts = ablate::sweep_w(&quick(), &[0.05, 0.25, 0.5, 0.75, 0.95]).unwrap();
    // delay non-increasing, energy non-decreasing along w
    for w in pts.windows(2) {
        assert!(w[1].mean_delay_s <= w[0].mean_delay_s + 1e-9);
        assert!(w[1].mean_energy_j >= w[0].mean_energy_j - 1e-9);
    }
}

#[test]
fn ablate_bandwidth_helps_but_saturates_toward_compute_floor() {
    // NOTE: rate = B·y(SNR) is NOT monotone point-wise (noise power grows
    // with B, stepping the CQI down), so we assert the robust facts: more
    // bandwidth helps vs the low end, and delay approaches a compute
    // floor it can never cross.
    let pts = ablate::sweep_bandwidth(&quick(), &[20.0, 200.0, 800.0]).unwrap();
    assert!(pts[1].mean_delay_s < pts[0].mean_delay_s);
    assert!(pts[2].mean_delay_s < pts[0].mean_delay_s);
    // compute-only floor: pure server-side compute at F_max for c=0
    let cfg = quick();
    let cm = edgesplit::coordinator::build_cost_model(&cfg);
    let floor = cm
        .delay
        .compute(0, &cfg.devices[0], &cfg.server, cfg.server.max_freq_hz);
    assert!(pts[2].mean_delay_s > floor);
}

// ---------------------------------------------------------------------------
// strategies × scheduler
// ---------------------------------------------------------------------------

#[test]
fn all_strategies_run_through_experiment_api() {
    for strat in [
        Strategy::Card,
        Strategy::ServerOnly,
        Strategy::DeviceOnly,
        Strategy::StaticCut(16),
        Strategy::RandomCut,
    ] {
        let experiment = ExperimentBuilder::from_config(quick())
            .channel_state(ChannelState::Normal)
            .strategy(strat)
            .build()
            .unwrap();
        let (summary, outcome) = experiment.run_summary().unwrap();
        assert_eq!(outcome.cells, 40, "{}", strat.name());
        assert!(summary.delay.mean() > 0.0);
    }
}

#[test]
fn card_cost_dominates_all_baselines_in_simulation() {
    let mk = |s| {
        let experiment = ExperimentBuilder::from_config(quick())
            .channel_state(ChannelState::Normal)
            .strategy(s)
            .build()
            .unwrap();
        experiment.run_summary().unwrap().0.cost.mean()
    };
    let card = mk(Strategy::Card);
    for s in [
        Strategy::ServerOnly,
        Strategy::DeviceOnly,
        Strategy::StaticCut(16),
        Strategy::RandomCut,
    ] {
        assert!(card <= mk(s) + 1e-9, "CARD U beaten by {}", s.name());
    }
}

#[test]
fn reduction_helper_matches_paper_arithmetic() {
    // 70.8% reduction: base 100 → ours 29.2
    assert!((reduction_pct(100.0, 29.2) - 70.8).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// config + CLI plumbing
// ---------------------------------------------------------------------------

#[test]
fn config_file_roundtrip_drives_simulation() {
    let toml = r#"
        [workload]
        rounds = 3
        [card]
        w = 0.9
        [[devices]]
        name = "only"
        freq_ghz = 1.0
        cores = 1024
        distance_m = 12
    "#;
    let cfg = ExpConfig::from_toml_str(toml).unwrap();
    cfg.validate().unwrap();
    let experiment = ExperimentBuilder::from_config(cfg)
        .channel_state(ChannelState::Good)
        .build()
        .unwrap();
    let recs = experiment.run_collect().unwrap();
    assert_eq!(recs.len(), 3);
    // w = 0.9 → delay-hungry → near-max frequency
    assert!(recs.iter().all(|r| r.freq_hz > 2.0e9));
}

#[test]
fn cli_parses_typical_invocations() {
    let specs = vec![
        FlagSpec { name: "rounds", value: Some("N"), help: "", default: Some("20") },
        FlagSpec { name: "state", value: Some("s"), help: "", default: Some("normal") },
        FlagSpec { name: "w", value: Some("f"), help: "", default: None },
    ];
    let argv: Vec<String> = ["fig4", "--rounds=5", "--state", "poor", "--w", "0.3"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let a = Args::parse(&argv, &specs).unwrap();
    assert_eq!(a.positional(), &["fig4".to_string()]);
    assert_eq!(a.usize_of("rounds").unwrap(), Some(5));
    assert_eq!(
        ChannelState::parse(a.str_of("state").unwrap()),
        Some(ChannelState::Poor)
    );
    assert_eq!(a.f64_of("w").unwrap(), Some(0.3));
}
