//! Decision-kernel bit-compat property suite (DESIGN.md §12, §13) —
//! driven through the unified experiment API (DESIGN.md §14).
//!
//! The acceptance bar for the kernel overhaul: for every scenario
//! preset × seed × strategy — and every channel process × mobility
//! combination — `ExecMode::Cached` (cut tables + CQI-keyed memo, any
//! thread count) produces a record stream **bit-identical** to
//! `ExecMode::Uncached` (kernel scan, cache bypassed) AND to
//! `ExecMode::Ref` (the pre-kernel reference path that re-derives the
//! model terms per cost call).  Random-cut participates too: it must
//! *bypass* the cache (it draws from the cell RNG) yet still match the
//! reference draw for draw.

use edgesplit::config::{scenario, ExpConfig, FadingModel, MobilityModel};
use edgesplit::coordinator::{RoundRecord, Scheduler, Strategy};
use edgesplit::exp::verify::verify_bit_identical;
use edgesplit::exp::{ExecMode, ExperimentBuilder};

const STRATEGIES: [Strategy; 5] = [
    Strategy::Card,
    Strategy::ServerOnly,
    Strategy::DeviceOnly,
    Strategy::StaticCut(13),
    Strategy::RandomCut,
];

#[test]
fn exec_modes_bit_identical_across_presets_seeds_strategies() {
    for sc in scenario::ALL {
        for seed in [1u64, 99] {
            for strategy in STRATEGIES {
                let run = |mode: ExecMode| -> Vec<RoundRecord> {
                    ExperimentBuilder::preset(sc.name)
                        .devices(17)
                        .seed(seed)
                        .rounds(5)
                        .strategy(strategy)
                        .threads(4)
                        .mode(mode)
                        .build()
                        .unwrap_or_else(|e| panic!("{}: {e}", sc.name))
                        .run_collect()
                        .unwrap()
                };
                // parallel + cached (the production path)...
                let cached = run(ExecMode::Cached);
                // ...vs the kernel scan with the cache bypassed...
                let uncached = run(ExecMode::Uncached);
                // ...vs the pre-kernel full-recompute reference
                let legacy = run(ExecMode::Ref);

                let ctx = format!("{} seed={seed} {}", sc.name, strategy.name());
                if let Err(e) = verify_bit_identical(&cached, &uncached) {
                    panic!("cached vs uncached [{ctx}]: {e:#}");
                }
                if let Err(e) = verify_bit_identical(&cached, &legacy) {
                    panic!("cached vs legacy [{ctx}]: {e:#}");
                }
            }
        }
    }
}

/// A heterogeneous-fleet base config with the given channel process
/// and (optionally) linear mobility layered on.
fn process_cfg(model: FadingModel, mobile: bool) -> ExpConfig {
    let mut cfg = scenario::HETEROGENEOUS_FLEET.config(11, 5).unwrap();
    cfg.workload.rounds = 6;
    cfg.churn = Default::default();
    cfg.channel.process.model = model;
    if mobile {
        cfg.mobility.model = MobilityModel::Linear;
        cfg.mobility.speed_mps = 3.0;
        cfg.mobility.round_s = 20.0;
    }
    cfg.validate().unwrap();
    cfg
}

#[test]
fn bit_compat_matrix_across_channel_processes_and_mobility() {
    let state = scenario::HETEROGENEOUS_FLEET.state;
    for model in FadingModel::ALL {
        for mobile in [false, true] {
            for strategy in STRATEGIES {
                let run = |mode: ExecMode, threads: usize| -> Vec<RoundRecord> {
                    ExperimentBuilder::from_config(process_cfg(model, mobile))
                        .channel_state(state)
                        .strategy(strategy)
                        .threads(threads)
                        .mode(mode)
                        .build()
                        .unwrap()
                        .run_collect()
                        .unwrap()
                };
                // parallel + cached (the production path), at several
                // thread counts (1 = the serial in-engine loop)...
                let cached = run(ExecMode::Cached, 4);
                let ctx = format!("{model:?} mobile={mobile} {}", strategy.name());
                for threads in [1, 8] {
                    if let Err(e) = verify_bit_identical(&cached, &run(ExecMode::Cached, threads)) {
                        panic!("thread-count divergence [{ctx}]: {e:#}");
                    }
                }
                // ...vs the kernel scan with the cache bypassed...
                if let Err(e) = verify_bit_identical(&cached, &run(ExecMode::Uncached, 1)) {
                    panic!("cached vs uncached [{ctx}]: {e:#}");
                }
                // ...vs the full-recompute reference
                if let Err(e) = verify_bit_identical(&cached, &run(ExecMode::Ref, 1)) {
                    panic!("cached vs legacy [{ctx}]: {e:#}");
                }
            }
        }
    }
}

/// Lag-1 Pearson autocorrelation of a series.
fn lag1_autocorr(xs: &[f64]) -> f64 {
    edgesplit::util::stats::pearson(&xs[..xs.len() - 1], &xs[1..])
}

#[test]
fn correlated_processes_produce_correlated_snr_traces() {
    let state = scenario::HETEROGENEOUS_FLEET.state;
    let trace = |model: FadingModel| -> Vec<f64> {
        let mut cfg = process_cfg(model, false);
        cfg.workload.rounds = 200;
        let sched = Scheduler::new(cfg, state, Strategy::Card);
        (0..200).map(|n| sched.device_round(n, 0).snr_up_db).collect()
    };
    let r_iid = lag1_autocorr(&trace(FadingModel::Iid));
    let r_markov = lag1_autocorr(&trace(FadingModel::Markov));
    let r_jakes = lag1_autocorr(&trace(FadingModel::Jakes));
    assert!(
        r_iid.abs() < 0.25,
        "iid SNR trace should be memoryless, lag-1 r = {r_iid}"
    );
    assert!(
        r_markov > 0.5,
        "markov SNR trace should be round-to-round correlated, lag-1 r = {r_markov}"
    );
    assert!(
        r_jakes > 0.5,
        "jakes SNR trace should be round-to-round correlated, lag-1 r = {r_jakes}"
    );
}

#[test]
fn random_cut_bypasses_cache_and_card_uses_it() {
    let cfg = |rounds: usize| {
        let mut c = scenario::HETEROGENEOUS_FLEET.config(12, 3).unwrap();
        c.workload.rounds = rounds;
        c.churn = Default::default();
        c
    };
    let state = scenario::HETEROGENEOUS_FLEET.state;
    let card = Scheduler::new(cfg(25), state, Strategy::Card);
    card.run_parallel(4);
    let (hits, misses) = card.cache_stats();
    assert!(hits > 0, "25 fading rounds must revisit CQI pairs");
    assert!(misses > 0, "first sight of each CQI pair must miss");

    let random = Scheduler::new(cfg(25), state, Strategy::RandomCut);
    random.run_parallel(4);
    assert_eq!(random.cache_stats(), (0, 0), "Random-cut must never touch the cache");
}

#[test]
fn cache_warmup_order_does_not_change_results() {
    // evaluate cells in two different orders (round-major vs
    // device-major): the cache fills in a different sequence, yet every
    // record must come out bit-identical
    let mut cfg = scenario::BURSTY_CHANNEL.config(9, 11).unwrap();
    cfg.workload.rounds = 6;
    cfg.churn = Default::default();
    let state = scenario::BURSTY_CHANNEL.state;
    let a = Scheduler::new(cfg.clone(), state, Strategy::Card);
    let b = Scheduler::new(cfg, state, Strategy::Card);

    let round_major: Vec<_> = (0..6)
        .flat_map(|n| (0..9).map(move |i| (n, i)))
        .map(|(n, i)| a.device_round(n, i))
        .collect();
    let mut device_major: Vec<_> = (0..9)
        .flat_map(|i| (0..6).map(move |n| (n, i)))
        .map(|(n, i)| b.device_round(n, i))
        .collect();
    device_major.sort_by_key(|r| (r.round, r.device_idx));
    if let Err(e) = verify_bit_identical(&round_major, &device_major) {
        panic!("warmup order changed records: {e:#}");
    }
}
