//! Bench A1/A2: ablation sweeps over the Table II design constants —
//! the weight w (delay↔energy dial), the compression ratio φ, and the
//! channel bandwidth.  Regenerates the sweep tables and times a sweep.
//!
//!   cargo bench --bench ablation_sweeps

use edgesplit::config::ExpConfig;
use edgesplit::sim::ablate;
use edgesplit::util::benchkit::{bb, Bencher};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExpConfig::paper();
    cfg.workload.rounds = 10;

    let w_vals = [0.0, 0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9, 1.0];
    let pts = ablate::sweep_w(&cfg, &w_vals)?;
    println!("{}\n", ablate::render("A1 — weight w sweep (Normal channel)", "w", &pts));
    // Pareto read-out: delay must fall and energy rise as w grows
    let d_first = pts.first().unwrap().mean_delay_s;
    let d_last = pts.last().unwrap().mean_delay_s;
    let e_first = pts.first().unwrap().mean_energy_j;
    let e_last = pts.last().unwrap().mean_energy_j;
    println!(
        "Pareto check: delay {:.1}s → {:.1}s (must fall), energy {:.0}J → {:.0}J (must rise)\n",
        d_first, d_last, e_first, e_last
    );

    let phi_vals = [0.02, 0.05, 0.1, 0.2, 0.4, 0.8, 1.0];
    let pts = ablate::sweep_phi(&cfg, &phi_vals)?;
    println!("{}\n", ablate::render("A2a — compression φ sweep (Poor channel)", "phi", &pts));

    let bw_vals = [10.0, 20.0, 50.0, 100.0, 200.0, 400.0];
    let pts = ablate::sweep_bandwidth(&cfg, &bw_vals)?;
    println!("{}\n", ablate::render("A2b — bandwidth sweep [MHz] (Normal channel)", "MHz", &pts));

    let mut b = Bencher::new("ablation_sweeps");
    let mut quick = cfg.clone();
    quick.workload.rounds = 4;
    b.bench("sweep_w_9_points_4_rounds", || {
        bb(ablate::sweep_w(&quick, &w_vals).unwrap());
    });
    b.report();
    Ok(())
}
