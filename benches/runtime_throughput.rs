//! Bench A4: PJRT runtime throughput — per-segment execution latency and
//! the chained-vs-fused train-step comparison (how much the dynamic-cut
//! flexibility costs).  Requires `artifacts/tiny` (`make artifacts`);
//! prints SKIP and exits cleanly when missing.
//!
//!   cargo bench --bench runtime_throughput

use edgesplit::data::{Batcher, Corpus};
use edgesplit::runtime::{artifact_dir, ArtifactStore, HostTensor, SplitExecutor};
use edgesplit::util::benchkit::Bencher;
use edgesplit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = artifact_dir("tiny");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: {dir:?} missing — run `make artifacts` first");
        return Ok(());
    }

    let mut store = ArtifactStore::open(&dir)?;
    let cfg = store.config.clone();
    println!(
        "artifacts '{}' — {} layers, d={}, batch {}x{}",
        cfg.name, cfg.n_layers, cfg.d_model, cfg.batch_size, cfg.seq_len
    );

    // ---- compile cost (one-time, amortized over the run) ----
    let t0 = std::time::Instant::now();
    store.compile_all()?;
    println!("compile_all: {:.2}s for {} segments\n", t0.elapsed().as_secs_f64(), store.compiled_count());

    // ---- per-segment latency ----
    let mut rng = Rng::new(5);
    let h_vals: Vec<f32> = (0..cfg.batch_size * cfg.seq_len * cfg.d_model)
        .map(|_| rng.gauss() as f32 * 0.1)
        .collect();
    let h = HostTensor::from_f32(&[cfg.batch_size, cfg.seq_len, cfg.d_model], &h_vals)?;
    let base: Vec<f32> = (0..cfg.base_layer_len).map(|_| rng.gauss() as f32 * 0.05).collect();
    let base = HostTensor::from_f32(&[cfg.base_layer_len], &base)?;
    let lora: Vec<f32> = (0..cfg.lora_layer_len).map(|_| rng.gauss() as f32 * 0.01).collect();
    let lora = HostTensor::from_f32(&[cfg.lora_layer_len], &lora)?;
    let grad = h.clone();
    let lr = HostTensor::from_f32(&[1], &[0.1])?;
    let g_l: Vec<f32> = (0..cfg.lora_layer_len).map(|_| rng.gauss() as f32 * 0.01).collect();
    let g_l = HostTensor::from_f32(&[cfg.lora_layer_len], &g_l)?;

    let tokens_per_step = (cfg.batch_size * cfg.seq_len) as f64;
    let mut b = Bencher::new("runtime_throughput");
    b.bench_throughput("layer_fwd", tokens_per_step, "tok", || {
        store.execute("layer_fwd", &[&h, &base, &lora]).unwrap();
    });
    b.bench_throughput("layer_bwd", tokens_per_step, "tok", || {
        store.execute("layer_bwd", &[&h, &base, &lora, &grad]).unwrap();
    });
    b.bench("adapter_sgd", || {
        store.execute("adapter_sgd", &[&lora, &g_l, &lr]).unwrap();
    });

    // ---- chained vs fused full step (ablation A4) ----
    let mk_exec = |seed: u64| -> anyhow::Result<SplitExecutor> {
        let store = ArtifactStore::open(&dir)?;
        let mut crng = Rng::new(seed);
        let corpus = Corpus::synthetic(0, 30_000, 0.1, &mut crng);
        let batcher = Batcher::new(corpus, cfg.batch_size, cfg.seq_len, seed);
        Ok(SplitExecutor::new(store, vec![batcher], 0.5, seed)?)
    };
    let mut chained = mk_exec(11)?;
    let mut fast = mk_exec(11)?;
    let mut fused = mk_exec(11)?;
    let mut step = 0usize;
    let rc = b.bench_throughput("train_step_chained_host", tokens_per_step, "tok", || {
        chained.train_step(0, 3, step).unwrap();
        step += 1;
    });
    let chained_mean = rc.mean_s;
    let mut fstep = 0usize;
    let rd = b.bench_throughput("train_step_chained_devres", tokens_per_step, "tok", || {
        fast.train_step_device(0, 3, fstep).unwrap();
        fstep += 1;
    });
    let devres_mean = rd.mean_s;
    let rf = b.bench_throughput("train_step_fused", tokens_per_step, "tok", || {
        fused.fused_train_step(0).unwrap();
    });
    let fused_mean = rf.mean_s;
    b.report();

    println!(
        "\nA4 / §Perf L3:\n  host-path chained   : {:.1} ms/step\n  device-resident     : {:.1} ms/step  ({:.2}x speedup — params + activations stay on device)\n  fused train_step    : {:.1} ms/step\n  devres/fused overhead = {:.2}x — the remaining price of runtime-dynamic cut selection",
        chained_mean * 1e3,
        devres_mean * 1e3,
        chained_mean / devres_mean,
        fused_mean * 1e3,
        devres_mean / fused_mean
    );
    Ok(())
}
