//! Bench A3: CARD complexity — the paper claims O(I) (Alg. 1 does I+1
//! cost evaluations after the closed-form f*).  We time `decide` for
//! models of 8..512 layers and fit the scaling exponent.
//!
//!   cargo bench --bench card_scaling

use edgesplit::config::{ExpConfig, WorkloadSpec};
use edgesplit::coordinator::{Card, CostModel};
use edgesplit::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LinkRates, LlmArch};
use edgesplit::util::benchkit::{bb, Bencher};
use edgesplit::util::stats::linreg;
use edgesplit::util::table::Table;

fn cost_model_with_layers(n_layers: usize, w: &WorkloadSpec, weight: f64) -> CostModel {
    let mut arch = LlmArch::llama1b();
    arch.n_layers = n_layers;
    let fl = FlopModel::new(&arch, w);
    CostModel::new(
        DelayModel::new(fl.clone(), DataSizeModel::new(&arch, w), w),
        EnergyModel::new(fl, w.local_epochs),
        weight,
    )
}

fn main() {
    let cfg = ExpConfig::paper();
    let rates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };

    let sizes = [8usize, 16, 32, 64, 128, 256, 512];
    let mut b = Bencher::new("card_scaling");
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut t = Table::new("A3 — CARD cost vs model depth I", &["I", "mean decide time"]);
    for &i in &sizes {
        let cm = cost_model_with_layers(i, &cfg.workload, cfg.card.w);
        let card = Card::new(&cm, &cfg.server);
        let res = b.bench(&format!("decide_I_{i}"), || {
            bb(card.decide(&cfg.devices[2], rates));
        });
        xs.push((i as f64).ln());
        ys.push(res.mean_s.ln());
        t.row(vec![i.to_string(), format!("{:.2} µs", res.mean_s * 1e6)]);
    }
    t.print();

    let (slope, _) = linreg(&xs, &ys);
    println!(
        "\nlog-log scaling exponent: {slope:.2} (paper claims O(I) ⇒ exponent ≈ 1; \
         sub-linear readings mean fixed overhead still dominates at small I)"
    );
    b.report();
}
