//! Bench F4: regenerates Fig. 4 — training delay and server energy for
//! CARD vs Server-only vs Device-only across Good/Normal/Poor channels —
//! and prints the paper's headline reductions next to ours.
//!
//!   cargo bench --bench fig4_comparison

use edgesplit::config::ExpConfig;
use edgesplit::sim::fig4;
use edgesplit::util::benchkit::{bb, Bencher};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExpConfig::paper();
    cfg.workload.rounds = 20;

    let r = fig4::run(&cfg)?;
    println!("{}\n", r.render());

    // shape assertions, printed for the experiment log
    let ok_delay = r.delay_reduction_vs_device_only_pct > 40.0;
    let ok_energy = r.energy_reduction_vs_server_only_pct > 25.0;
    println!(
        "shape check: delay reduction {} ({}), energy reduction {} ({})",
        if ok_delay { "PASS" } else { "FAIL" },
        format_args!("{:.1}%", r.delay_reduction_vs_device_only_pct),
        if ok_energy { "PASS" } else { "FAIL" },
        format_args!("{:.1}%", r.energy_reduction_vs_server_only_pct),
    );

    // timing: full figure regeneration cost
    let mut b = Bencher::new("fig4_comparison");
    b.bench("fig4_full_grid_20_rounds", || {
        bb(fig4::run(&cfg).unwrap());
    });
    b.report();
    Ok(())
}
