//! Bench F3: regenerates Fig. 3(a)+(b) — per-device cut-layer and
//! server-frequency decisions across 20 training rounds under Rayleigh
//! block fading — and times the decision loop itself.
//!
//!   cargo bench --bench fig3_decisions

use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::{build_cost_model, Card};
use edgesplit::model::LinkRates;
use edgesplit::sim::fig3;
use edgesplit::util::benchkit::{bb, Bencher};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExpConfig::paper();
    cfg.workload.rounds = 20;

    // ---- the figure itself ----
    // Normal: stable per-capability endpoints.  Poor: fading flips the
    // decisions across rounds (the dynamic behaviour Fig. 3 highlights).
    let names: Vec<String> = cfg.devices.iter().map(|d| d.name.clone()).collect();
    let r_poor = fig3::run(&cfg, ChannelState::Poor)?;
    println!("--- Poor channel (dynamic regime) ---\n{}\n", r_poor.render(&names));
    let r = fig3::run(&cfg, ChannelState::Normal)?;
    println!("--- Normal channel ---\n{}\n", r.render(&names));

    // paper-structure checks, printed so regressions are visible in CI logs
    let m = r.cut_matrix();
    let endpoints = m
        .iter()
        .flatten()
        .filter(|&&c| c == 0 || c == r.n_layers)
        .count();
    println!(
        "endpoint decisions: {endpoints}/{} (paper: all decisions at 0 or {})",
        r.rounds * r.n_devices,
        r.n_layers
    );
    let mean_cut = |row: &Vec<usize>| row.iter().sum::<usize>() as f64 / row.len() as f64;
    println!(
        "mean cut by device (capability ↓): {:?}  (paper: decreasing 32 → 0)\n",
        m.iter().map(|r| format!("{:.0}", mean_cut(r))).collect::<Vec<_>>()
    );

    // ---- decision-loop timing ----
    let cm = build_cost_model(&cfg);
    let card = Card::new(&cm, &cfg.server);
    let rates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };
    let mut b = Bencher::new("fig3_decisions");
    b.bench("card_decide_one_device", || {
        bb(card.decide(&cfg.devices[2], rates));
    });
    b.bench_throughput("card_decide_fleet_of_5", 5.0, "decision", || {
        for d in &cfg.devices {
            bb(card.decide(d, rates));
        }
    });
    b.report();
    Ok(())
}
