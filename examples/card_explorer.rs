//! CARD decision-landscape explorer: where does the optimal cut flip?
//!
//! Sweeps (a) device compute capability, (b) distance/SNR, (c) the
//! weight w — printing the decision each time.  This is the intuition
//! behind Fig. 3: the optimum is an endpoint {0, I} whose side depends
//! on the device/channel/objective trade-off.
//!
//!   cargo run --release --example card_explorer

use edgesplit::config::{ChannelState, DeviceSpec, ExpConfig};
use edgesplit::coordinator::{build_cost_model, Card};
use edgesplit::net::Channel;
use edgesplit::util::rng::Rng;
use edgesplit::util::table::Table;

fn device(ghz: f64, cores: f64, dist: f64) -> DeviceSpec {
    DeviceSpec {
        name: format!("{ghz:.1}GHz/{cores:.0}c"),
        platform: "synthetic".into(),
        freq_hz: ghz * 1e9,
        cores,
        flops_per_cycle: 2.0,
        distance_m: dist,
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig::paper();
    let cm = build_cost_model(&cfg);
    let mut rng = Rng::new(1);

    // (a) capability sweep at fixed distance, Normal channel, no fading
    let mut ch_spec = cfg.channel.clone();
    ch_spec.fading = false;
    let channel = Channel::new(ch_spec.clone(), ChannelState::Normal);
    let mut t = Table::new(
        "(a) capability sweep — 20 m, Normal channel",
        &["device", "cut c*", "f* [GHz]", "U"],
    );
    for ghz in [0.3, 0.5, 0.7, 0.9, 1.1, 1.3] {
        let dev = device(ghz, 2048.0, 20.0);
        let link = channel.realize(&dev, &mut rng);
        let card = Card::new(&cm, &cfg.server);
        let d = card.decide(&dev, link.rates);
        t.row(vec![
            dev.name.clone(),
            d.cut.to_string(),
            format!("{:.2}", d.freq_hz / 1e9),
            format!("{:.3}", d.cost),
        ]);
    }
    t.print();

    // (b) distance sweep for a mid-tier device, Poor channel
    let channel = Channel::new(ch_spec.clone(), ChannelState::Poor);
    let mut t = Table::new(
        "\n(b) distance sweep — 0.7 GHz / 1024 cores, Poor channel",
        &["distance", "SNR up [dB]", "cut c*", "U"],
    );
    for dist in [5.0, 10.0, 15.0, 20.0, 30.0, 45.0] {
        let dev = device(0.7, 1024.0, dist);
        let link = channel.realize(&dev, &mut rng);
        let card = Card::new(&cm, &cfg.server);
        let d = card.decide(&dev, link.rates);
        t.row(vec![
            format!("{dist:.0} m"),
            format!("{:.1}", link.snr_up_db),
            d.cut.to_string(),
            format!("{:.3}", d.cost),
        ]);
    }
    t.print();

    // (c) weight sweep for Device 3 — the delay/energy dial
    let channel = Channel::new(ch_spec, ChannelState::Normal);
    let mut t = Table::new(
        "\n(c) weight w sweep — Device 3 (0.7 GHz / 1792 cores)",
        &["w", "cut c*", "f* [GHz]", "delay [s]", "energy [J]"],
    );
    for w in [0.0, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut cfg_w = cfg.clone();
        cfg_w.card.w = w;
        let cm_w = build_cost_model(&cfg_w);
        let dev = cfg.devices[2].clone();
        let link = channel.realize(&dev, &mut rng);
        let card = Card::new(&cm_w, &cfg_w.server);
        let d = card.decide(&dev, link.rates);
        t.row(vec![
            format!("{w:.1}"),
            d.cut.to_string(),
            format!("{:.2}", d.freq_hz / 1e9),
            format!("{:.1}", d.delay_s),
            format!("{:.1}", d.energy_j),
        ]);
    }
    t.print();
    println!("\nReading: cut flips 0 → I as capability grows / objective tilts to energy;");
    println!("f* climbs with w (delay pressure) and falls when energy dominates.");
    Ok(())
}
