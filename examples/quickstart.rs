//! Quickstart: the public API in ~60 lines.
//!
//! Builds the paper's testbed (Tables I+II), realizes a wireless channel,
//! asks CARD for a cut-layer + frequency decision per device, and runs a
//! few analytic training rounds.
//!
//!   cargo run --release --example quickstart

use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::{build_cost_model, Strategy};
use edgesplit::exp::ExperimentBuilder;
use edgesplit::net::Channel;
use edgesplit::util::rng::Rng;
use edgesplit::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> anyhow::Result<()> {
    // 1. the paper's setup: 5 Jetson-class devices + RTX-4060Ti server
    let mut cfg = ExpConfig::paper();
    cfg.workload.rounds = 8;
    cfg.validate()?;

    // 2. one CARD decision per device under a Normal channel
    let cost_model = build_cost_model(&cfg);
    let channel = Channel::new(cfg.channel.clone(), ChannelState::Normal);
    let mut rng = Rng::new(cfg.seed);

    let mut t = Table::new(
        "CARD decisions (Normal channel)",
        &["device", "cut c*", "f* [GHz]", "round delay", "server energy"],
    );
    for dev in &cfg.devices {
        let link = channel.realize(dev, &mut rng);
        let d = Strategy::Card.decide(&cost_model, &cfg.server, dev, link.rates, &mut rng);
        t.row(vec![
            dev.name.clone(),
            d.cut.to_string(),
            format!("{:.2}", d.freq_hz / 1e9),
            fmt_secs(d.delay_s),
            fmt_joules(d.energy_j),
        ]);
    }
    t.print();

    // 3. full multi-round simulation through the unified experiment
    //    API, CARD vs the two paper baselines — builder in, summary out
    println!();
    let mut cmp = Table::new(
        "8 rounds, mean per-round cost (Normal channel)",
        &["strategy", "delay", "server energy"],
    );
    for strat in [Strategy::Card, Strategy::ServerOnly, Strategy::DeviceOnly] {
        let experiment = ExperimentBuilder::from_config(cfg.clone())
            .channel_state(ChannelState::Normal)
            .strategy(strat)
            .build()?;
        let (s, _) = experiment.run_summary()?;
        cmp.row(vec![
            strat.name(),
            fmt_secs(s.delay.mean()),
            fmt_joules(s.energy.mean()),
        ]);
    }
    cmp.print();
    println!("\nNext: `cargo run --release --example edge_finetune` for REAL split training.");
    Ok(())
}
