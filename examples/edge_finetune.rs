//! END-TO-END driver: REAL split LoRA fine-tuning through the whole
//! three-layer stack (Pallas kernels → JAX segments → HLO artifacts →
//! PJRT → Rust coordinator), on a synthetic multi-device corpus, with
//! CARD making the cut/frequency decision every round under a fading
//! channel.  Logs the loss curve; the run is recorded in EXPERIMENTS.md.
//!
//!   make artifacts && cargo run --release --example edge_finetune
//!
//! Flags (positional, optional): [arch] [steps] [lr]
//!   arch   tiny|small   (default small; falls back to tiny if absent)
//!   steps  total optimizer steps across all devices (default 300)
//!   lr     LoRA learning rate (default 0.5)

use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::{Strategy, TrainBackend};
use edgesplit::data::{Batcher, Corpus};
use edgesplit::exp::ExperimentBuilder;
use edgesplit::runtime::{artifact_dir, ArtifactStore, SplitExecutor};
use edgesplit::sim::reduction_pct;
use edgesplit::util::rng::Rng;
use edgesplit::util::stats;
use edgesplit::util::table::{fmt_secs, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arch = args.first().map(|s| s.as_str()).unwrap_or("small");
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let lr: f32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.5);

    // resolve artifacts (prefer requested arch, fall back to tiny)
    let dir = if artifact_dir(arch).join("manifest.json").exists() {
        artifact_dir(arch)
    } else {
        eprintln!("artifacts/{arch} missing; falling back to tiny — run `make artifacts`");
        artifact_dir("tiny")
    };
    let store = ArtifactStore::open(&dir)?;
    let mcfg = store.config.clone();
    println!(
        "== edge_finetune: {} ({} layers, d_model {}, {}x{} tokens/batch, lr {lr}) ==",
        mcfg.name, mcfg.n_layers, mcfg.d_model, mcfg.batch_size, mcfg.seq_len
    );

    let mut cfg = ExpConfig::paper();
    cfg.seed = 1234;
    // the cost model must describe the model actually being trained
    cfg.workload.arch = mcfg.name.clone();
    cfg.workload.batch_size = mcfg.batch_size;
    cfg.workload.seq_len = mcfg.seq_len;
    let n_dev = cfg.devices.len();

    // per-device non-IID corpora
    let batchers: Vec<Batcher> = (0..n_dev)
        .map(|i| {
            let mut rng = Rng::new(cfg.seed ^ (7000 + i as u64));
            let corpus = Corpus::synthetic(i, 80_000, 0.15, &mut rng);
            Batcher::new(corpus, mcfg.batch_size, mcfg.seq_len, cfg.seed ^ (9000 + i as u64))
        })
        .collect();
    let mut executor = SplitExecutor::new(store, batchers, lr, cfg.seed)?;

    // CARD decides per round under a Normal fading channel; the
    // unified experiment API drives the real backend alongside
    cfg.workload.rounds = steps.div_ceil(cfg.workload.local_epochs * n_dev).max(1);
    let experiment = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(ChannelState::Normal)
        .strategy(Strategy::Card)
        .build()?;

    let t0 = std::time::Instant::now();
    let records = experiment.run_trained(&mut executor)?;
    let wall = t0.elapsed().as_secs_f64();

    // ---- loss curve ----
    println!("\nloss curve (one optimizer step per line-block of 10):");
    let losses: Vec<f64> = executor.loss_log.iter().map(|x| x.1).collect();
    for (i, chunk) in losses.chunks(10).enumerate() {
        let mean = stats::mean(chunk);
        let bar_len = ((mean / losses[0]).min(1.0) * 60.0) as usize;
        println!("steps {:>4}-{:<4} loss {mean:7.4} {}", i * 10, i * 10 + chunk.len() - 1, "#".repeat(bar_len));
    }

    // ---- per-round table (first/last few) ----
    let mut t = Table::new(
        "rounds (CARD decisions + modeled costs + real losses)",
        &["round", "device", "cut", "loss", "modeled delay", "wallclock"],
    );
    for r in records.iter().take(5).chain(records.iter().rev().take(3).rev()) {
        t.row(vec![
            r.round.to_string(),
            r.device_name.to_string(),
            r.cut.to_string(),
            r.loss.map(|l| format!("{l:.4}")).unwrap_or_default(),
            fmt_secs(r.delay_s),
            r.backend_wallclock_s.map(fmt_secs).unwrap_or_default(),
        ]);
    }
    t.print();

    let first = losses.first().copied().unwrap_or(f64::NAN);
    let last10 = stats::mean(&losses[losses.len().saturating_sub(10)..]);
    println!("\nsummary:");
    println!("  steps                 : {}", losses.len());
    println!("  initial loss          : {first:.4} (ln 256 = {:.4})", (256f64).ln());
    println!("  final loss (mean@10)  : {last10:.4}");
    println!("  loss reduction        : {:.1}%", reduction_pct(first, last10));
    println!("  adapters consistent   : {}", executor.aggregator.is_consistent());
    println!("  adapter bytes moved   : {:.1} MB", (executor.aggregator.bytes_distributed + executor.aggregator.bytes_collected) / 1e6);
    println!("  total wallclock       : {}", fmt_secs(wall));
    anyhow::ensure!(last10 < first - 0.5, "loss did not drop enough — regression!");
    println!("\nE2E OK — all three layers composed.");
    Ok(())
}

// silence unused-import lint when TrainBackend is only used via Scheduler
#[allow(unused)]
fn _assert_backend_impl(e: &mut SplitExecutor) -> &mut dyn TrainBackend {
    e
}
