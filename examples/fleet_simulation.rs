//! Fleet-scale simulation: 50 heterogeneous synthetic devices (sampled
//! around the Table I tiers), CARD vs all baselines, across every
//! channel state — the "massive mobile devices" scenario from the
//! paper's abstract that the 5-device testbed cannot show.
//!
//!   cargo run --release --example fleet_simulation

use edgesplit::config::{ChannelState, ExpConfig};
use edgesplit::coordinator::Strategy;
use edgesplit::devices::Fleet;
use edgesplit::exp::ExperimentBuilder;
use edgesplit::sim::reduction_pct;
use edgesplit::util::rng::Rng;
use edgesplit::util::table::{fmt_joules, fmt_secs, Table};

fn main() -> anyhow::Result<()> {
    let n_devices = 50;
    let rounds = 10;

    let mut rng = Rng::new(2026);
    let fleet = Fleet::synthetic(n_devices, &mut rng);
    let mut cfg = ExpConfig::paper();
    cfg.devices = fleet.devices.clone();
    cfg.workload.rounds = rounds;
    cfg.validate()?;

    println!(
        "fleet: {n_devices} devices, throughput {:.1}–{:.1} TFLOP/s, {rounds} rounds\n",
        fleet.by_capability().last().unwrap().throughput() / 1e12,
        fleet.by_capability()[0].throughput() / 1e12,
    );

    let strategies = [
        Strategy::Card,
        Strategy::ServerOnly,
        Strategy::DeviceOnly,
        Strategy::StaticCut(16),
        Strategy::RandomCut,
    ];

    let mut t = Table::new(
        "fleet results (mean per device-round)",
        &["channel", "strategy", "delay", "server energy", "mean cut"],
    );
    let mut card_delay = Vec::new();
    let mut dev_only_delay = Vec::new();
    let mut card_energy = Vec::new();
    let mut srv_only_energy = Vec::new();

    for state in ChannelState::ALL {
        for strat in strategies {
            // fleet rounds run K devices concurrently; results are
            // bit-identical to the serial path for the same seed
            let experiment = ExperimentBuilder::from_config(cfg.clone())
                .channel_state(state)
                .strategy(strat)
                .build()?;
            let (s, _) = experiment.run_summary()?;
            let mean_cut = s.mean_cut();
            t.row(vec![
                state.name().into(),
                strat.name(),
                fmt_secs(s.delay.mean()),
                fmt_joules(s.energy.mean()),
                format!("{mean_cut:.1}"),
            ]);
            match strat {
                Strategy::Card => {
                    card_delay.push(s.delay.mean());
                    card_energy.push(s.energy.mean());
                }
                Strategy::DeviceOnly => dev_only_delay.push(s.delay.mean()),
                Strategy::ServerOnly => srv_only_energy.push(s.energy.mean()),
                _ => {}
            }
        }
    }
    t.print();

    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\nfleet headline: delay −{:.1}% vs device-only, energy −{:.1}% vs server-only",
        reduction_pct(avg(&dev_only_delay), avg(&card_delay)),
        reduction_pct(avg(&srv_only_energy), avg(&card_energy)),
    );
    println!("(paper, 5 devices: −70.8% delay, −53.1% energy — structure preserved at 10× fleet size)");
    Ok(())
}
